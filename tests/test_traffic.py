"""Traffic subsystem: arrival-process determinism, queue invariants
(work conservation, M/M/1 sojourn, Little's law), load-aware routing
parity across the three paths, hedging, and the herding regression."""
import jax
import numpy as np
import pytest

from repro.core import dataset, platform, routing
from repro.core.agent import Agent
from repro.core.batch_routing import make_engine
from repro.core.routing import RoutingConfig
from repro.kernels import ops, ref
from repro.traffic import (
    ARRIVAL_PROCESSES,
    FleetTrafficSim,
    QueueConfig,
    diurnal_arrivals,
    flash_crowd_arrivals,
    ideal_platform,
    merge_arrivals,
    mmpp_arrivals,
    poisson_arrivals,
    replica_fleet,
)

SERVERS = dataset.build_server_pool(seed=0)
QUERY_TEXTS = [q.text for q in dataset.build_query_dataset(n=64, seed=1)]


# ---------------------------------------------------------------------------
# Arrival processes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(ARRIVAL_PROCESSES))
def test_arrival_processes_seeded_deterministic(name):
    gen = ARRIVAL_PROCESSES[name]
    a = gen(jax.random.PRNGKey(3), 5.0, 200.0)
    b = gen(jax.random.PRNGKey(3), 5.0, 200.0)
    c = gen(jax.random.PRNGKey(4), 5.0, 200.0)
    np.testing.assert_array_equal(a, b)
    assert a.size > 0 and not (
        a.size == c.size and np.array_equal(a, c)
    ), "different keys must give different streams"
    assert (np.diff(a) >= 0).all() and a[0] >= 0.0 and a[-1] < 200.0


def test_poisson_rate_and_exponential_gaps():
    arr = poisson_arrivals(jax.random.PRNGKey(0), 10.0, 2000.0)
    assert arr.size == pytest.approx(20000, rel=0.05)
    gaps = np.diff(arr)
    # exponential: mean ~ 1/rate, CV ~ 1
    assert gaps.mean() == pytest.approx(0.1, rel=0.05)
    assert gaps.std() / gaps.mean() == pytest.approx(1.0, rel=0.1)


def test_diurnal_peak_vs_trough():
    period = 400.0
    # phase 0: peak around t=period/4, trough around 3*period/4
    arr = diurnal_arrivals(
        jax.random.PRNGKey(1), 8.0, 40 * period, depth=0.8, period_s=period
    )
    phase = np.mod(arr, period) / period
    peak = ((phase > 0.0) & (phase < 0.5)).sum()
    trough = ((phase > 0.5) & (phase < 1.0)).sum()
    assert peak > 1.5 * trough


def test_mmpp_burstier_than_poisson():
    key = jax.random.PRNGKey(2)
    mmpp = mmpp_arrivals(key, 6.0, 4000.0, burst_factor=8.0)
    pois = poisson_arrivals(key, 6.0, 4000.0)
    assert mmpp.size == pytest.approx(pois.size, rel=0.25)

    def dispersion(arr):  # index of dispersion of 10 s counts
        counts = np.bincount((arr // 10.0).astype(int))
        return counts.var() / counts.mean()

    assert dispersion(pois) < 2.0          # Poisson: ~1
    assert dispersion(mmpp) > 2.0 * dispersion(pois)


def test_flash_crowd_spikes_then_decays():
    arr = flash_crowd_arrivals(
        jax.random.PRNGKey(5), 4.0, 300.0, spike_factor=10.0, spike_at_s=100.0,
        decay_s=30.0,
    )
    before = ((arr > 40.0) & (arr < 100.0)).sum() / 60.0
    spike = ((arr >= 100.0) & (arr < 130.0)).sum() / 30.0
    late = (arr >= 250.0).sum() / 50.0
    assert spike > 3.0 * before            # the crowd arrives
    assert late < 2.0 * before             # and decays away


def test_merge_arrivals_superimposes():
    a = poisson_arrivals(jax.random.PRNGKey(0), 3.0, 100.0)
    b = poisson_arrivals(jax.random.PRNGKey(1), 3.0, 100.0)
    m = merge_arrivals(a, b)
    assert m.size == a.size + b.size and (np.diff(m) >= 0).all()


# ---------------------------------------------------------------------------
# Queue invariants (trivial routing: pure queueing dynamics)
# ---------------------------------------------------------------------------

def _single_server_sim(capacity=1, queue_limit=10_000, service_ms=200.0,
                       inflation=0.0, seed=0):
    servers = replica_fleet(1)
    plat = ideal_platform(servers, seed=0, horizon_s=4000.0)
    return FleetTrafficSim(
        plat, lambda text, hist, load: 0,
        QueueConfig(capacity=capacity, queue_limit=queue_limit,
                    base_service_ms=service_ms, inflation=inflation),
        retry_budget=0, seed=seed,
    )


def test_simulator_deterministic_and_conserves_requests():
    arr = poisson_arrivals(jax.random.PRNGKey(0), 6.0, 60.0)
    reports = []
    for _ in range(2):
        servers = replica_fleet(4)
        plat = ideal_platform(servers, seed=0)
        router = routing.make_router(
            "sonar_lb", servers, RoutingConfig(gamma=0.35, top_s=4, top_k=4)
        )
        sim = FleetTrafficSim(
            plat, router, QueueConfig(capacity=2, queue_limit=8),
            retry_budget=2, seed=1,
        )
        reports.append(sim.run(arr, QUERY_TEXTS[:4]))
    r1, r2 = reports
    assert r1.per_server_served == r2.per_server_served
    assert r1.goodput_rps == r2.goodput_rps and r1.p99_ms == r2.p99_ms
    assert r1.n_completed + r1.n_failed == r1.n_offered


def test_work_conservation_and_capacity():
    """No request waits while a slot is free; occupancy never exceeds c."""
    sim = _single_server_sim(capacity=3, service_ms=250.0)
    arr = poisson_arrivals(jax.random.PRNGKey(7), 9.0, 120.0)
    rep = sim.run(arr, ["q"])
    done = [r for r in rep.requests if r.done]
    assert len(done) == rep.n_offered       # unbounded queue: all complete
    starts = np.asarray([r.t_start_ms for r in done])
    ends = starts + np.asarray([r.service_ms for r in done])
    arrivals = np.asarray([r.t_arrival_ms for r in done])

    def occupancy(t):
        return int(((starts <= t) & (ends > t)).sum())

    for r in done:
        assert occupancy(r.t_start_ms - 1e-6) <= 3
        if r.t_start_ms > r.t_arrival_ms + 1e-9:   # it waited...
            assert occupancy(r.t_start_ms - 1e-6) == 3  # ...only at capacity
    # busy-time integral == sum of service durations (everything drained)
    q = sim.queues[0]
    assert q.stats.busy_ms == pytest.approx(q.stats.service_ms_sum, rel=1e-9)
    _ = arrivals


def test_mm1_sojourn_matches_theory():
    """M/M/1 at rho=0.6: mean sojourn = 1/(mu - lambda) = 500 ms."""
    sim = _single_server_sim(capacity=1, service_ms=200.0)   # mu = 5/s
    arr = poisson_arrivals(jax.random.PRNGKey(11), 3.0, 1500.0)  # lambda = 3/s
    rep = sim.run(arr, ["q"])
    done = [r for r in rep.requests if r.done]
    sojourn = np.asarray(
        [(r.t_start_ms + r.service_ms) - r.t_arrival_ms for r in done]
    )
    assert sojourn.mean() == pytest.approx(500.0, rel=0.2)


def test_littles_law_on_long_poisson_run():
    """N_bar = lambda_eff * W_bar, with N_bar measured by time sampling."""
    sim = _single_server_sim(capacity=2, service_ms=300.0)
    arr = poisson_arrivals(jax.random.PRNGKey(13), 4.0, 1000.0)  # rho = 0.6
    rep = sim.run(arr, ["q"])
    done = [r for r in rep.requests if r.done]
    arrivals = np.asarray([r.t_arrival_ms for r in done])
    departs = np.asarray([r.t_start_ms + r.service_ms for r in done])
    T = departs.max()
    grid = np.arange(0.0, T, 1000.0)
    n_bar = np.mean(
        [(np.sum((arrivals <= t) & (departs > t))) for t in grid]
    )
    w_bar_s = np.mean(departs - arrivals) / 1000.0
    lam_eff = len(done) / (T / 1000.0)
    assert n_bar == pytest.approx(lam_eff * w_bar_s, rel=0.15)


def test_service_time_inflation_under_load():
    q = QueueConfig(capacity=4, inflation=2.0, base_service_ms=100.0)
    from repro.traffic.queueing import ServerQueue

    sq = ServerQueue(q)
    assert sq.service_time(100.0) == pytest.approx(100.0)     # idle
    sq.in_service = 4
    assert sq.service_time(100.0) == pytest.approx(300.0)     # rho=1 -> 3x


# ---------------------------------------------------------------------------
# Load-aware routing parity (scalar == batched == kernel path)
# ---------------------------------------------------------------------------

def test_load_aware_parity_scalar_vs_batched():
    plat = platform.NetMCPPlatform(SERVERS, scenario="hybrid", seed=1)
    hist = plat.latency_window(3000)
    rng = np.random.default_rng(0)
    load = rng.random(len(SERVERS)).astype(np.float32) * 2.0
    cfg = RoutingConfig(gamma=0.5)
    router = routing.make_router("sonar_lb", SERVERS, cfg)
    for use_kernels in (False, True):
        engine = make_engine("sonar_lb", SERVERS, cfg, use_kernels=use_kernels)
        dec = engine.route_texts(QUERY_TEXTS, hist, load)
        for i, q in enumerate(QUERY_TEXTS):
            d = router.select(q, hist, load)
            assert (d.server_idx, d.tool_idx) == (
                int(dec.server_idx[i]), int(dec.tool_idx[i])
            ), f"kernels={use_kernels} query {i}"


def test_load_term_changes_picks_and_off_means_sonar():
    plat = platform.NetMCPPlatform(SERVERS, scenario="hybrid", seed=1)
    hist = plat.latency_window(3000)
    e_sonar = make_engine("sonar", SERVERS)
    e_lb = make_engine("sonar_lb", SERVERS)
    base = e_sonar.route_texts(QUERY_TEXTS, hist)
    off = e_lb.route_texts(QUERY_TEXTS, hist)        # no load vector
    np.testing.assert_array_equal(base.server_idx, off.server_idx)
    np.testing.assert_array_equal(base.tool_idx, off.tool_idx)
    # saturate every currently-picked server: picks must move
    load = np.zeros(len(SERVERS), np.float32)
    load[np.unique(np.asarray(base.server_idx))] = 4.0
    on = e_lb.route_texts(QUERY_TEXTS, hist, load)
    assert (np.asarray(on.server_idx) != np.asarray(base.server_idx)).any()


def test_fused_select_kernel_load_term_matches_oracle():
    rng = np.random.default_rng(42)
    n_q, n_t = 24, 120
    sel = rng.standard_normal((n_q, n_t)).astype(np.float32) * 3
    sel = np.where(rng.random((n_q, n_t)) < 0.4, sel, -np.inf)
    qos = rng.random((n_t,)).astype(np.float32) * 2 - 1
    load = rng.random((n_q, n_t)).astype(np.float32) * 3
    import jax.numpy as jnp

    got = ops.fused_select(
        jnp.asarray(sel), jnp.asarray(sel), jnp.asarray(qos), jnp.asarray(load),
        k=8, alpha=0.4, beta=0.4, gamma=0.3,
    )
    want = ref.fused_select_ref(
        jnp.asarray(sel), jnp.asarray(sel), jnp.asarray(qos), jnp.asarray(load),
        k=8, alpha=0.4, beta=0.4, gamma=0.3,
    )
    assert (np.asarray(got[0]) == np.asarray(want[0])).all()
    for g, w in zip(got[1:], want[1:]):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Hedging + retry budget
# ---------------------------------------------------------------------------

def test_agent_hedging_races_runner_up():
    plat = platform.NetMCPPlatform(SERVERS, scenario="high_latency", seed=3)
    router = routing.make_router("prag", SERVERS)
    queries = dataset.build_query_dataset(n=10, seed=0)
    base = Agent(plat, router).run_task(queries[0], 1000)
    plat2 = platform.NetMCPPlatform(SERVERS, scenario="high_latency", seed=3)
    router2 = routing.make_router("prag", SERVERS)
    hedged = Agent(
        plat2, router2, hedge_ms=100.0, retry_budget=2
    ).run_task(queries[0], 1000)
    assert hedged.n_calls > base.n_calls          # the duplicate was fired
    assert hedged.completion_ms <= base.completion_ms


def test_agent_defaults_unchanged_without_hedging():
    plat = platform.NetMCPPlatform(SERVERS, scenario="hybrid", seed=1)
    router = routing.make_router("sonar", SERVERS)
    queries = dataset.build_query_dataset(n=8, seed=0)
    a = Agent(plat, router).run_benchmark(queries, ticks_per_query=60)
    plat2 = platform.NetMCPPlatform(SERVERS, scenario="hybrid", seed=1)
    router2 = routing.make_router("sonar", SERVERS)
    b = Agent(plat2, router2, hedge_ms=None, retry_budget=None).run_benchmark(
        queries, ticks_per_query=60
    )
    for x, y in zip(a, b):
        assert x.completion_ms == y.completion_ms and x.n_calls == y.n_calls


def test_simulator_hedging_rescues_herded_tail():
    """Hedging pays off exactly where requests sit behind a herded queue
    while other replicas idle — i.e. under a load-blind router: the
    duplicate escapes the hot server and cuts the tail (at the cost of a
    little wasted work, as real tail-at-scale hedging does)."""
    servers = replica_fleet(4)
    cfg = RoutingConfig(top_s=4, top_k=4)
    arr = poisson_arrivals(jax.random.PRNGKey(3), 6.0, 45.0)
    reports = {}
    for hedge in (None, 600.0):
        plat = ideal_platform(servers, seed=0)
        router = routing.make_router("sonar", servers, cfg)
        sim = FleetTrafficSim(
            plat, router,
            QueueConfig(capacity=2, queue_limit=8, base_service_ms=500.0,
                        inflation=1.0),
            hedge_ms=hedge, retry_budget=2, seed=1,
        )
        reports[hedge] = sim.run(arr, QUERY_TEXTS[:4])
    hedged, plain = reports[600.0], reports[None]
    assert hedged.n_hedges > 0
    assert hedged.p99_ms < plain.p99_ms
    assert hedged.n_completed >= 0.9 * plain.n_completed


def test_hedging_on_single_replica_fleet_is_a_noop():
    """Nowhere to hedge to: the simulator must skip the hedge (and not
    crash) when every station already hosts a copy."""
    servers = replica_fleet(1)
    plat = ideal_platform(servers, seed=0)
    sim = FleetTrafficSim(
        plat, lambda text, hist, load: 0,
        QueueConfig(capacity=1, queue_limit=50, base_service_ms=400.0),
        hedge_ms=100.0, retry_budget=2, seed=0,
    )
    arr = poisson_arrivals(jax.random.PRNGKey(0), 4.0, 30.0)
    rep = sim.run(arr, ["q"])
    assert rep.n_hedges == 0
    assert rep.n_completed + rep.n_failed == rep.n_offered


# ---------------------------------------------------------------------------
# Herding regression: load-blind collapse vs SONAR-LB spreading
# ---------------------------------------------------------------------------

def _burst_report(algo, n_simultaneous=12, n_replicas=6):
    servers = replica_fleet(n_replicas)
    plat = ideal_platform(servers, seed=0)
    cfg = RoutingConfig(gamma=0.35, top_s=n_replicas, top_k=n_replicas)
    router = routing.make_router(algo, servers, cfg)
    sim = FleetTrafficSim(
        plat, router,
        QueueConfig(capacity=2, queue_limit=n_simultaneous, base_service_ms=400.0),
        retry_budget=0, seed=1,
    )
    return sim.run(np.zeros(n_simultaneous), QUERY_TEXTS[:1])


def test_simultaneous_burst_herds_without_load_term():
    """The signature failure: an instantaneous burst of identical requests
    all lands on the single top-scored replica when routing is load-blind
    (no completions yet, so the feed-forward loop cannot help), while
    SONAR-LB spreads it across the fleet."""
    blind = _burst_report("sonar")
    lb = _burst_report("sonar_lb")
    assert blind.max_share == 1.0              # total herding
    assert lb.max_share <= 0.5                 # spread across the fleet
    assert lb.p99_ms < blind.p99_ms


def test_offered_load_past_saturation_regression():
    """Sustained overload of one server's capacity: SONAR-LB strictly wins
    goodput and p99 and fails less (tiny version of benchmarks/offered_load)."""
    servers = replica_fleet(4)
    cfg = RoutingConfig(gamma=0.35, top_s=4, top_k=4)
    arr = poisson_arrivals(jax.random.PRNGKey(0), 8.0, 45.0)  # sat = 4 rps
    reports = {}
    for algo in ("sonar", "sonar_lb"):
        plat = ideal_platform(servers, seed=0)
        router = routing.make_router(algo, servers, cfg)
        sim = FleetTrafficSim(
            plat, router,
            QueueConfig(capacity=2, queue_limit=8, base_service_ms=500.0,
                        inflation=1.0),
            retry_budget=2, seed=0,
        )
        reports[algo] = sim.run(arr, QUERY_TEXTS[:4])
    blind, lb = reports["sonar"], reports["sonar_lb"]
    assert lb.goodput_rps > blind.goodput_rps
    assert lb.p99_ms < blind.p99_ms
    assert lb.n_failed <= blind.n_failed
    assert lb.n_drop_events < blind.n_drop_events


# ---------------------------------------------------------------------------
# Gateway load-awareness
# ---------------------------------------------------------------------------

def test_gateway_load_aware_batch_spreads():
    from repro.serving.gateway import SonarGateway, replica_pool

    archs = [("qwen2-7b", "dense")] * 8
    texts = ["generate a chat completion response"] * 32
    blind = SonarGateway(replica_pool(archs), use_kernels=True, algo="sonar")
    lb = SonarGateway(
        replica_pool(archs), use_kernels=True, algo="sonar_lb",
        slots_per_replica=4, lb_chunk=8,
    )
    picks_blind = {r.replica_idx for r in blind.route_batch(texts)}
    picks_lb = {r.replica_idx for r in lb.route_batch(texts)}
    assert len(picks_blind) == 1               # herds on one replica
    assert len(picks_lb) >= 3                  # spreads chunk by chunk
    assert np.all(lb.in_flight == 0.0)         # accounting drains


def test_gateway_begin_finish_accounting():
    from repro.serving.gateway import SonarGateway, replica_pool

    archs = [("qwen2-7b", "dense")] * 4
    gw = SonarGateway(replica_pool(archs), algo="sonar_lb", slots_per_replica=2)
    picks = [gw.begin("generate text").replica_idx for _ in range(4)]
    assert len(set(picks)) >= 2                # in-flight pushes traffic away
    for idx in picks:
        gw.finish(idx, 25.0)
    assert np.all(gw.in_flight == 0.0)


# ---------------------------------------------------------------------------
# Hedge edge cases: excluded-everywhere dispatch, late-losing siblings
# ---------------------------------------------------------------------------

def _two_station_sim(draws, hedge_ms=50.0):
    from repro.traffic.simulator import FleetTrafficSim

    servers = replica_fleet(2)
    plat = ideal_platform(servers, seed=0, horizon_s=600.0)
    sim = FleetTrafficSim(
        plat, lambda text, hist, load: 0,
        QueueConfig(capacity=1, queue_limit=4, base_service_ms=1.0,
                    inflation=0.0),
        hedge_ms=hedge_ms, retry_budget=2, seed=0,
    )
    sim._draws = np.asarray(draws, np.float64)
    sim._draw_i = 0
    sim._heap, sim._seq = [], 0
    return sim


def _drain(sim):
    import heapq

    from repro.traffic.simulator import _ARRIVAL, _FINISH

    while sim._heap:
        _t, _, kind, payload = heapq.heappop(sim._heap)
        if kind == _ARRIVAL:
            sim._dispatch(payload, _t)
        elif kind == _FINISH:
            sim._finish(payload, _t)
        else:
            sim._hedge(payload, _t)


def test_dispatch_with_every_station_excluded_is_a_clean_noop():
    """`_dispatch`'s hedge-placement fallback: when every station is
    excluded there is nowhere to put the copy — the dispatch must return
    without offering work, scheduling events, or leaking live copies."""
    from repro.traffic.simulator import Request

    sim = _two_station_sim([10.0, 10.0])
    req = Request(rid=0, text="q", t_arrival_ms=0.0, budget=2)
    sim._dispatch(req, 0.0, exclude=frozenset({0, 1}))
    assert req.live_copies == 0 and not req.done and not req.failed
    assert sim._heap == []                      # no FINISH/HEDGE scheduled
    assert all(q.stats.offered == 0 for q in sim.queues)
    assert req.n_routes == 1                    # the route itself happened


def test_hedge_sibling_finishing_after_primary_does_not_double_complete():
    """The losing hedge copy is in service when the primary wins: its
    later FINISH must hit the `req.done` early-return — one completion,
    one feed-forward record, and the wasted work stays on the queue
    stats (work conservation)."""
    from repro.traffic.simulator import _ARRIVAL, Request

    # draws: blocker=60 (pins station 0), primary=10, hedge copy=100
    sim = _two_station_sim([60.0, 10.0, 100.0], hedge_ms=50.0)
    blocker = Request(rid=0, text="q", t_arrival_ms=0.0, budget=0)
    req = Request(rid=1, text="q", t_arrival_ms=0.0, budget=2)
    sim._push(0.0, _ARRIVAL, blocker)
    sim._push(0.0, _ARRIVAL, req)
    _drain(sim)
    assert req.n_hedges == 1 and req.hedged
    assert req.done and not req.failed
    assert req.server_idx == 0                  # the primary won at t=70
    assert req.live_copies == 0 and blocker.live_copies == 0
    # exactly one completion per request, even though both copies ran
    assert sim.obs.registry.value("sim_completed_total") == 2.0
    served = sum(q.stats.served for q in sim.queues)
    assert served == 3                          # blocker + primary + waste
    assert sim.queues[1].stats.served == 1      # the hedge ran to the end


def test_hedge_sibling_cancelled_in_queue_when_hedge_wins():
    """The mirror case: the hedge wins while the primary still waits —
    the queued sibling is cancelled (no double service, no double
    completion) and the winner's station is recorded."""
    from repro.traffic.simulator import _ARRIVAL, Request

    # blocker pins station 0 for 500ms; the hedge (draw 20) wins on 1
    sim = _two_station_sim([500.0, 10.0, 20.0], hedge_ms=50.0)
    blocker = Request(rid=0, text="q", t_arrival_ms=0.0, budget=0)
    req = Request(rid=1, text="q", t_arrival_ms=0.0, budget=2)
    sim._push(0.0, _ARRIVAL, blocker)
    sim._push(0.0, _ARRIVAL, req)
    _drain(sim)
    assert req.done and req.server_idx == 1
    assert req.live_copies == 0
    assert sim.queues[0].stats.served == 1      # only the blocker ran there
    assert sim.obs.registry.value("sim_completed_total") == 2.0


def test_hedged_fleet_conserves_work_and_never_double_completes():
    """End-to-end invariant sweep under heavy hedging: every request
    resolves exactly once (done xor failed), no copy leaks, and station
    work = completions + wasted hedge copies."""
    servers = replica_fleet(3)
    plat = ideal_platform(servers, seed=0)
    cfg = RoutingConfig(top_s=3, top_k=3)
    router = routing.make_router("sonar", servers, cfg)
    sim = FleetTrafficSim(
        plat, router,
        QueueConfig(capacity=1, queue_limit=6, base_service_ms=400.0),
        hedge_ms=200.0, retry_budget=2, seed=2,
    )
    arr = poisson_arrivals(jax.random.PRNGKey(7), 5.0, 40.0)
    rep = sim.run(arr, QUERY_TEXTS[:4])
    assert rep.n_hedges > 0
    reqs = rep.requests
    assert all(r.done != r.failed for r in reqs), (
        "every request resolves exactly once"
    )
    assert all(r.live_copies == 0 for r in reqs)
    assert rep.n_completed == sum(r.done for r in reqs)
    assert rep.n_completed + rep.n_failed == rep.n_offered
    assert sim.obs.registry.value("sim_completed_total") == rep.n_completed
    served = sum(q.stats.served for q in sim.queues)
    assert served >= rep.n_completed            # wasted copies ran too
    assert served <= rep.n_completed + rep.n_hedges
