"""Histogram edge semantics + the clock-source convention.

The log-scale `Histogram` promises: every observation is counted (below-lo
clamps to bucket 0, at/above-hi to the last bucket), quantiles are clamped
to the observed [vmin, vmax], and the relative quantile error is bounded
by one bucket ratio — `10^(1/per_decade) - 1`, ~7.5% at the default 32
buckets per decade (the figure the `obs/metrics.py` docstring cites).

The clock regression: elapsed-time spans across the benchmark/launch
stack are measured with `time.monotonic`, so a backwards wall-clock step
(NTP correction mid-run) can never produce a negative latency span.
"""
import math
import time

import numpy as np
import pytest

from repro.obs.metrics import Histogram


def test_quantile_q0_and_q1_clamp_to_observed_range():
    h = Histogram("t")
    vals = [0.5, 3.0, 42.0, 999.0]
    h.observe_many(vals)
    assert h.quantile(0.0) == min(vals)   # clamped to vmin
    assert h.quantile(1.0) == max(vals)   # clamped to vmax
    assert h.vmin == min(vals) and h.vmax == max(vals)


def test_observations_below_lo_and_at_hi_are_counted():
    h = Histogram("t", lo=1.0, hi=1000.0)
    h.observe(0.001)          # far below lo -> bucket 0
    h.observe(1000.0)         # exactly hi -> last bucket
    h.observe(5e6)            # far above hi -> last bucket
    assert h.count == 3
    assert sum(h.counts) == 3
    assert h.counts[0] == 1
    assert h.counts[-1] == 2
    # quantiles stay inside the *observed* range, not the bucket range
    assert h.vmin <= h.p50 <= h.vmax
    # bucket knowledge saturates at hi: the top quantile reports the hi
    # edge, while the exact max survives in vmax (and the snapshot)
    assert h.quantile(1.0) == 1000.0
    assert h.snapshot()["max"] == 5e6


def test_single_observation_all_quantiles_exact():
    h = Histogram("t")
    h.observe(7.25)
    for q in (0.0, 0.5, 0.99, 1.0):
        assert h.quantile(q) == 7.25    # vmin/vmax clamp beats bucket edges
    assert h.mean == 7.25


def test_empty_histogram_is_neutral():
    h = Histogram("t")
    assert h.count == 0 and h.mean == 0.0 and h.quantile(0.5) == 0.0
    snap = h.snapshot()
    assert snap["min"] == 0.0 and snap["max"] == 0.0


def test_quantile_relative_error_within_one_bucket_ratio():
    """The documented bound: bucket-interpolated quantiles are within
    `10^(1/per_decade) - 1` (~7.5% at 32/decade) of the exact sample
    quantile for any distribution inside [lo, hi)."""
    rng = np.random.default_rng(0)
    samples = np.exp(rng.normal(3.0, 1.2, size=5000))   # log-normal ms
    h = Histogram("t", lo=1e-3, hi=1e6)                 # defaults
    h.observe_many(samples)
    bound = 10.0 ** (1.0 / h.per_decade) - 1.0
    assert bound == pytest.approx(0.0746, abs=5e-4)     # the "~7.5%" figure
    for q in (0.05, 0.25, 0.50, 0.90, 0.99, 0.999):
        exact = float(np.quantile(samples, q))
        est = h.quantile(q)
        rel = abs(est - exact) / exact
        assert rel <= bound + 1e-9, (
            f"q={q}: est={est:.4f} exact={exact:.4f} rel={rel:.4%} "
            f"> bound {bound:.4%}"
        )


def test_mean_is_exact_not_bucketed():
    h = Histogram("t")
    vals = [0.123, 4.56, 789.0, 0.0001, 1e5]
    h.observe_many(vals)
    assert h.mean == pytest.approx(sum(vals) / len(vals), rel=1e-12)


def test_bucket_edges_are_geometric():
    h = Histogram("t", lo=1.0, hi=100.0, per_decade=4)
    ratio = 10.0 ** (1.0 / 4.0)
    for i in range(1, h.n_buckets):
        assert h._edge(i) / h._edge(i - 1) == pytest.approx(ratio)
    assert h.n_buckets == math.ceil(2 * 4)


def test_latency_spans_survive_backwards_wall_clock(monkeypatch):
    """Regression for the time.time() -> time.monotonic() sweep: step the
    wall clock BACKWARDS during a timed benchmark run (an NTP correction
    mid-measurement) and assert every reported span is still
    non-negative.  With wall-clock arithmetic the per-request costs here
    would come out negative."""
    import benchmarks.fleet_sim as fleet_sim

    wall = iter(np.linspace(1e9, 1e9 - 3600.0, 10_000))  # ticks backwards
    monkeypatch.setattr(time, "time", lambda: float(next(wall)))
    res = fleet_sim.main(
        print_fn=lambda *_: None, n_per_template=1, n_queries=2, n_iter=1
    )
    assert res["us_per_request_batched"] >= 0.0
    assert res["us_per_request_scalar"] >= 0.0
    assert res["speedup"] >= 0.0
