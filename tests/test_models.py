"""Per-arch reduced smoke tests + sequence-mixer oracle equivalence +
prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import mamba as mb
from repro.models import xlstm as xl
from repro.models.api import get_model
from repro.models.attention import _chunked_attn, _naive_attn
from repro.serving.engine import pad_cache_to_capacity


def _batch(cfg, B, S, with_labels=True, seed=0):
    rng = np.random.default_rng(seed)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if with_labels:
        b["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.n_vision_tokens:
        b["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_vision_tokens, cfg.d_model)), jnp.float32
        )
    if cfg.is_encoder_decoder:
        b["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_audio_frames, cfg.d_model)), jnp.float32
        )
    return b


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_smoke_train_step(arch):
    """Reduced config: one forward/train step, assert shapes + no NaNs."""
    cfg = configs.get_reduced(arch)
    model = get_model(cfg)
    params, axes = model.init_params(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), arch
    logits, _, _ = model.forward(params, batch, mode="train")
    S_total = S + (cfg.n_vision_tokens or 0)
    assert logits.shape == (B, S_total, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_prefill_decode_consistency(arch):
    """decode(cache from prefill(x[:S])) == train-forward(x[:S+1]) last logits.

    capacity_factor is raised so MoE never drops tokens — capacity dropping
    legitimately differs between a T=S and a T=S+1 forward.  f32 params:
    this is a logic test, and bf16 rounding differs between the chunked
    prefill path and the stepwise decode path (~3e-2 on mamba)."""
    import dataclasses

    cfg = dataclasses.replace(
        configs.get_reduced(arch), capacity_factor=8.0, dtype="float32"
    )
    model = get_model(cfg)
    params, _ = model.init_params(jax.random.PRNGKey(1))
    B, S = 2, 12
    full = _batch(cfg, B, S + 1, with_labels=False, seed=3)
    pre = dict(full)
    pre["tokens"] = full["tokens"][:, :S]

    logits_pre, cache = model.prefill(params, pre)
    cache_len = S + (cfg.n_vision_tokens or 0)
    cache = pad_cache_to_capacity(cache, model.cache_axes(), cache_len + 4)
    logits_dec, _ = model.decode_step(
        params, cache, full["tokens"][:, S : S + 1], jnp.int32(cache_len)
    )

    ref, _, _ = model.forward(params, full, mode="train")
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0], np.float32),
        np.asarray(ref[:, -1], np.float32),
        rtol=2e-2, atol=2e-2,
    )
    # and prefill's last logits match the train forward at position S-1
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, -1], np.float32),
        np.asarray(ref[:, -2], np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_ssd_chunked_matches_sequential():
    rng = np.random.default_rng(0)
    B, L, nH, P, N = 2, 100, 4, 8, 16
    xh = jnp.asarray(rng.standard_normal((B, L, nH, P)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((B, L, nH)), jnp.float32))
    Bm = jnp.asarray(rng.standard_normal((B, L, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, L, N)), jnp.float32)
    a_log = jnp.asarray(rng.standard_normal(nH), jnp.float32) * 0.5
    for chunk in (8, 32, 128):
        y1, h1 = mb.ssd_chunked(xh, dt, Bm, Cm, a_log, chunk=chunk)
        y2, h2 = mb.ssd_scan_ref(xh, dt, Bm, Cm, a_log)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=1e-4, atol=1e-4)


def test_ssd_unroll_equals_scan():
    rng = np.random.default_rng(3)
    B, L, nH, P, N = 1, 64, 2, 8, 8
    xh = jnp.asarray(rng.standard_normal((B, L, nH, P)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((B, L, nH)), jnp.float32))
    Bm = jnp.asarray(rng.standard_normal((B, L, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, L, N)), jnp.float32)
    a_log = jnp.zeros(nH)
    y1, _ = mb.ssd_chunked(xh, dt, Bm, Cm, a_log, chunk=16, unroll=False)
    y2, _ = mb.ssd_chunked(xh, dt, Bm, Cm, a_log, chunk=16, unroll=True)
    # scan vs unrolled lowering reassociates f32 sums; allow ulp-level noise
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-7)


def test_mlstm_chunked_matches_sequential():
    rng = np.random.default_rng(1)
    B, L, nH, dh = 2, 90, 2, 16
    q, k, v = (jnp.asarray(rng.standard_normal((B, L, nH, dh)), jnp.float32) for _ in range(3))
    lf = jax.nn.log_sigmoid(jnp.asarray(rng.standard_normal((B, L, nH)) + 1, jnp.float32))
    li = jnp.asarray(rng.standard_normal((B, L, nH)), jnp.float32)
    for chunk in (8, 32):
        h1, s1 = xl.mlstm_chunked(q, k, v, lf, li, chunk=chunk)
        h2, s2 = xl.mlstm_scan_ref(q, k, v, lf, li)
        np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(s1["C"]), np.asarray(s2["C"]), rtol=2e-4, atol=2e-4)


def test_mamba_decode_matches_full_forward():
    """Token-by-token decode == full-sequence forward (state correctness)."""
    cfg = configs.get_reduced("jamba-1.5-large-398b")
    rng = np.random.default_rng(5)
    from repro.nn.core import InitCtx, unzip

    p, _ = unzip(mb.mamba_init(InitCtx(key=jax.random.PRNGKey(0), dtype=jnp.float32), cfg))
    B, L = 1, 10
    x = jnp.asarray(rng.standard_normal((B, L, cfg.d_model)), jnp.float32)
    y_full, _ = mb.mamba_apply(p, cfg, x)
    state = mb.init_mamba_state(cfg, B)
    ys = []
    for t in range(L):
        y_t, state = mb.mamba_decode(p, cfg, x[:, t : t + 1], state)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step), rtol=2e-3, atol=2e-3)


def test_chunked_attn_matches_naive_cross():
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((2, 70, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 50, 4, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 50, 4, 16)), jnp.float32)
    o1 = _chunked_attn(q, k, v, causal=False, chunk=16)
    o2 = _naive_attn(q, k, v, causal=False, kv_len=None)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_param_counts_match_init(arch):
    """Analytic param_counts == actual initialized parameter count
    (MODEL_FLOPS for the roofline derives from this)."""
    cfg = configs.get_reduced(arch)
    model = get_model(cfg)
    params, _ = model.init_params(jax.random.PRNGKey(0))
    actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    analytic = cfg.param_counts()["total"]
    assert abs(actual - analytic) / actual < 0.005, (arch, actual, analytic)
