"""Serving engine (continuous batching) + SONAR gateway."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import latency as latlib
from repro.models.api import get_model
from repro.serving.engine import Request, ServeEngine, pad_cache_to_capacity
from repro.serving.gateway import SonarGateway, replica_pool


@pytest.fixture(scope="module")
def small_model():
    cfg = configs.get_reduced("internlm2-1.8b")
    model = get_model(cfg)
    params, _ = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _greedy_reference(model, params, prompt, n_new, cap=64):
    """Manual prefill + decode loop (no batching engine)."""
    logits, cache = model.prefill(params, {"tokens": jnp.asarray(prompt[None])})
    cache = pad_cache_to_capacity(cache, model.cache_axes(), cap)
    toks = [int(np.argmax(np.asarray(logits[0, -1])))]
    clen = len(prompt)
    for _ in range(n_new - 1):
        logits, cache = model.decode_step(
            params, cache, jnp.asarray([[toks[-1]]], jnp.int32), jnp.int32(clen)
        )
        toks.append(int(np.argmax(np.asarray(logits[0, -1]))))
        clen += 1
    return toks


def test_engine_matches_manual_decode(small_model):
    cfg, model, params = small_model
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    want = _greedy_reference(model, params, prompt, 5)
    eng = ServeEngine(model, params, n_slots=2, cap=64)
    req = Request(rid=0, tokens=prompt, max_new_tokens=5)
    eng.submit(req)
    eng.run()
    assert req.done and req.generated == want


def test_engine_continuous_batching(small_model):
    cfg, model, params = small_model
    rng = np.random.default_rng(1)
    reqs = [
        Request(rid=i, tokens=rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                max_new_tokens=3 + i % 3)
        for i in range(5)
    ]
    eng = ServeEngine(model, params, n_slots=2, cap=32)  # 5 reqs through 2 slots
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.generated) == r.max_new_tokens for r in reqs)


def test_gateway_avoids_downed_replica():
    replicas = replica_pool([("yi-6b", "dense")] * 4)
    profiles = [
        latlib.outage_profile(probability=0.95),
        latlib.ideal_profile(),
        latlib.ideal_profile(),
        latlib.high_latency_profile(),
    ]
    gw = SonarGateway(replicas, profiles=profiles, seed=0)
    for _ in range(20):
        res = gw.route("generate a chat reply about travel")
    rep = gw.report()
    assert rep["failure_rate"] == 0.0
    assert rep["al_ms"] < 200.0


def test_gateway_batched_kernel_path_agrees():
    replicas = replica_pool([("yi-6b", "dense")] * 8)
    profiles = [latlib.ideal_profile()] * 4 + [latlib.high_latency_profile()] * 4
    seq = SonarGateway(replicas, profiles=profiles, seed=3)
    bat = SonarGateway(replicas, profiles=profiles, seed=3, use_kernels=True)
    texts = ["text generation request"] * 6
    r1 = [seq.route(t) for t in texts]
    r2 = bat.route_batch(texts)
    # both must avoid the high-latency half of the fleet
    assert all(r.replica_idx < 4 for r in r1)
    assert all(r.replica_idx < 4 for r in r2)


def test_engine_pending_and_drain(small_model):
    """`pending` tracks queued + in-slot requests and `drain` finishes
    them all (the graceful-shutdown path of the serving front-end)."""
    cfg, model, params = small_model
    rng = np.random.default_rng(2)
    eng = ServeEngine(model, params, n_slots=2, cap=32)
    assert eng.pending == 0
    reqs = [
        Request(rid=i, tokens=rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                max_new_tokens=2)
        for i in range(3)
    ]
    for r in reqs:
        eng.submit(r)
    assert eng.pending == 3
    eng.step()                       # admits up to n_slots, decodes once
    assert 0 < eng.pending <= 3
    eng.drain()
    assert eng.pending == 0 and all(r.done for r in reqs)


def test_pad_cache_noop_when_at_capacity(small_model):
    cfg, model, params = small_model
    cache = model.init_cache(2, 16)
    out = pad_cache_to_capacity(cache, model.cache_axes(), 16)
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(out)):
        assert a.shape == b.shape
