"""Observability invariants (docs/observability.md).

Covers: Chrome-trace export validity, span nesting/monotonicity, the
phase-tiling identity (queue_wait + encode + dispatch + merge == serve,
per request), deterministic span replay from `MicroBatchPump.flush_log`,
metrics<->accounting conservation (property-tested against
`MicroBatcher.check_accounting`), jit-safe `DeviceRouteStats` (padding
exclusion + deferred drain), the unified `SonarGateway.report()` source
of truth, the audit tap's bit-exact score recomposition across all
algorithms (riding the parity-suite fixtures), simulator/chaos trace
emission, histogram quantile bounds, and the dashboard renderers.
"""
import asyncio
import io
import json
import types

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.chaos import build_schedule, standard_fault_mix
from repro.core import dataset, routing
from repro.core import latency as latlib
from repro.core.latency import OFFLINE_MS
from repro.core.platform import NetMCPPlatform
from repro.core.routing import RoutingConfig
from repro.obs import (
    AuditTap,
    DeviceRouteStats,
    Histogram,
    LiveDashboard,
    MetricsRegistry,
    Observability,
    render_dashboard,
)
from repro.obs.trace import SpanTracer, emit_chaos_events
from repro.serving.frontend import AsyncServingGateway
from repro.serving.gateway import SonarGateway, replica_pool
from repro.serving.microbatch import BatchingPolicy, MicroBatcher, MicroBatchPump
from repro.traffic import FleetTrafficSim, QueueConfig, poisson_arrivals, replica_fleet
from repro.traffic.source import LiveRequest, request_schedule

from repro.core import adaptive  # noqa: F401  registers sonar_adapt, so the
                                 # audit sweep below covers it deterministically

POOL = dataset.build_server_pool(seed=0)
ALGOS = sorted(routing.ALGORITHMS)
assert "sonar_adapt" in ALGOS
TEXTS = [
    "what is the latest news about the stock market today",
    "search the web for current weather information",
    "find recent articles about machine learning research",
    "look up live election results online",
]


def _make_gateway(n_replicas, algo, seed=0, obs=None):
    replicas = replica_pool([("yi-6b", "dense")] * n_replicas)
    profiles = [latlib.ideal_profile() for _ in range(n_replicas)]
    return SonarGateway(
        replicas, profiles=profiles, algo=algo, seed=seed,
        use_kernels=True, device_telemetry=True, obs=obs,
    )


@pytest.fixture(scope="module")
def pump_run():
    """One fully-instrumented pump replay shared by the trace tests."""
    obs = Observability(trace=True, jit_stats=True)
    gw = _make_gateway(3, "sonar_lb", obs=obs)
    schedule = request_schedule(
        "flash_crowd", jax.random.PRNGKey(0), 400.0, 0.25, TEXTS,
        deadline_ms=30.0, spike_factor=3.0,
    )
    pump = MicroBatchPump(gw, BatchingPolicy(
        max_batch=4, max_wait_ms=2.0, slack_ms=0.0, queue_limit=8,
        pad_batches=True,
    ))
    rep = pump.replay(schedule)
    assert rep.n_routed > 0 and rep.n_flushes > 0
    return obs, gw, pump, rep


# ---------------------------------------------------------------------------
# Chrome trace export
# ---------------------------------------------------------------------------

def _assert_valid_chrome_trace(payload):
    events = payload["traceEvents"]
    assert isinstance(events, list) and events
    assert payload["displayTimeUnit"] == "ms"
    for ev in events:
        assert isinstance(ev["name"], str) and ev["name"]
        assert ev["ph"] in {"X", "i", "C", "M"}
        if ev["ph"] == "M":
            continue
        assert isinstance(ev["ts"], (int, float))
        if ev["ph"] == "X":
            assert ev["dur"] >= 0.0
        if ev["ph"] == "i":
            assert ev["s"] == "t"
    assert any(ev["ph"] == "X" for ev in events)
    assert any(ev["ph"] == "M" for ev in events)


def test_chrome_trace_json_valid(pump_run, tmp_path):
    obs, _, _, _ = pump_run
    path = tmp_path / "trace.json"
    obs.tracer.write(str(path))
    payload = json.loads(path.read_text())
    _assert_valid_chrome_trace(payload)
    assert payload["otherData"]["n_events"] == len(obs.tracer.events)
    assert payload["otherData"]["n_dropped"] == 0


def test_tracer_disabled_and_bounded_buffer():
    off = SpanTracer(enabled=False)
    off.add_span("x", 0.0, 1.0)
    off.instant("y")
    off.counter("z", {"v": 1})
    with off.span("w"):
        pass
    assert off.events == []

    small = SpanTracer(enabled=True, clock_ms=lambda: 0.0, max_events=3)
    for i in range(5):
        small.instant(f"e{i}", 0.0)
    assert len(small.events) == 3 and small.n_dropped == 2
    assert small.to_chrome_trace()["otherData"]["n_dropped"] == 2
    small.clear()
    assert small.events == [] and small.n_dropped == 0


def test_chaos_events_render_mask_intervals():
    sched = types.SimpleNamespace(
        n_servers=2,
        down=np.array([[False, True, True, False], [False] * 4]),
        degrade=np.array([[1.0, 1.0, 2.5, 1.0], [1.0] * 4]),
        stale=np.array([[False] * 4, [True, True, False, False]]),
    )
    tr = SpanTracer(enabled=True, clock_ms=lambda: 0.0)
    emit_chaos_events(tr, sched, dt_s=0.5)
    by_name = {}
    for ev in tr.events:
        by_name.setdefault(ev["name"], []).append(ev)
    # server 0 down over steps [1, 3) at 500 ms/step -> [500, 1500] ms
    (down,) = by_name["down"]
    assert down["pid"] == "chaos" and down["tid"] == 0
    assert down["ts"] == 500.0 * 1000 and down["dur"] == 1000.0 * 1000
    (inj,) = by_name["inject:down"]
    assert inj["ph"] == "i" and inj["ts"] == down["ts"]
    (deg,) = by_name["degraded"]
    assert deg["ts"] == 1000.0 * 1000 and deg["dur"] == 500.0 * 1000
    (stale,) = by_name["telemetry-stale"]
    assert stale["tid"] == 1 and stale["ts"] == 0.0 and stale["dur"] == 1000.0 * 1000
    # a None schedule or disabled tracer is a no-op
    emit_chaos_events(tr, None, dt_s=0.5)
    n = len(tr.events)
    emit_chaos_events(SpanTracer(enabled=False), sched, dt_s=0.5)
    assert len(tr.events) == n


# ---------------------------------------------------------------------------
# Span nesting / tiling / e2e-latency identity
# ---------------------------------------------------------------------------

def _spans(events, name, **match):
    out = []
    for ev in events:
        if ev["name"] != name or ev["ph"] != "X":
            continue
        if all(ev.get("args", {}).get(k) == v for k, v in match.items()):
            out.append(ev)
    return out


def test_span_nesting_and_phase_tiling(pump_run):
    obs, _, pump, rep = pump_run
    events = obs.tracer.events
    for fidx in range(rep.n_flushes):
        (flush,) = _spans(events, "flush", flush=fidx)
        t0, t1 = flush["ts"], flush["ts"] + flush["dur"]
        phases = [
            _spans(events, ph, flush=fidx)[0]
            for ph in ("encode", "dispatch", "merge")
        ]
        # contiguous, monotone, nested, and tiling the flush exactly
        cur = t0
        for ev in phases:
            assert np.isclose(ev["ts"], cur, rtol=1e-9, atol=1e-3)
            assert ev["dur"] >= 0.0
            cur = ev["ts"] + ev["dur"]
        assert np.isclose(cur, t1, rtol=1e-9, atol=1e-3)
        total = sum(ev["dur"] for ev in phases)
        assert np.isclose(total, flush["dur"], rtol=1e-9, atol=1e-3)


def test_request_spans_sum_to_e2e_latency(pump_run):
    """Acceptance identity: per-request queue_wait + encode + dispatch +
    merge spans reproduce the measured end-to-end serve latency."""
    obs, _, pump, rep = pump_run
    events = obs.tracer.events
    routed = [r for r in rep.results if not (r.shed or r.expired)]
    assert routed
    for res in routed:
        (serve,) = [
            e for e in _spans(events, "serve")
            if e["tid"] == res.rid and e["pid"] == "requests"
        ]
        (wait,) = [
            e for e in _spans(events, "queue_wait") if e["tid"] == res.rid
        ]
        fidx = serve["args"]["flush"]
        phase_ms = sum(
            _spans(events, ph, flush=fidx)[0]["dur"]
            for ph in ("encode", "dispatch", "merge")
        ) / 1000.0
        total_ms = wait["dur"] / 1000.0 + phase_ms
        assert np.isclose(total_ms, res.serve_ms, rtol=1e-9, atol=1e-6)
        assert np.isclose(serve["dur"] / 1000.0, res.serve_ms,
                          rtol=1e-9, atol=1e-6)
        # nesting: queue_wait starts with serve, ends at the flush start
        assert wait["ts"] == serve["ts"]
        assert wait["ts"] + wait["dur"] <= serve["ts"] + serve["dur"] + 1e-3
    # shed / expired requests are instants, not spans
    names = [e["name"] for e in events if e["ph"] == "i"]
    assert names.count("shed") == rep.n_shed
    assert names.count("expired") == rep.n_expired


def test_replay_spans_reproduces_live_trace(pump_run):
    obs, _, pump, _ = pump_run
    span_names = {"flush", "encode", "dispatch", "merge",
                  "serve", "queue_wait"}
    live = [e for e in obs.tracer.events if e["name"] in span_names]
    replayed = pump.replay_spans().events
    assert live == replayed
    # replay of a replay is byte-identical
    assert json.dumps(replayed) == json.dumps(pump.replay_spans().events)


def test_async_frontend_emits_the_same_span_taxonomy():
    async def drive():
        obs = Observability(trace=True)
        gw = _make_gateway(2, "sonar", obs=obs)
        srv = AsyncServingGateway(gw, BatchingPolicy(
            max_batch=2, max_wait_ms=1.0, queue_limit=8,
        ))
        await srv.start()
        res = await asyncio.gather(*[srv.submit(t) for t in TEXTS])
        await srv.close()
        return obs, res

    obs, res = asyncio.run(drive())
    assert all(not (r.shed or r.expired) for r in res)
    serve = _spans(obs.tracer.events, "serve")
    assert len(serve) == len(TEXTS)
    for r in res:
        (sp,) = [e for e in serve if e["tid"] == r.rid]
        (wait,) = [
            e for e in _spans(obs.tracer.events, "queue_wait")
            if e["tid"] == r.rid
        ]
        assert np.isclose(sp["dur"] / 1000.0, r.serve_ms,
                          rtol=1e-9, atol=1e-3)
        assert wait["dur"] <= sp["dur"] + 1e-3
    assert obs.registry.value("serving_offered_total") == len(TEXTS)


# ---------------------------------------------------------------------------
# Metrics: conservation, registry semantics, histogram bounds
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_registry_conservation_matches_check_accounting(seed):
    """The registry counters satisfy the exact conservation law
    `MicroBatcher.check_accounting` enforces, over random offer/take
    interleavings with deadlines and queue overflow."""
    rng = np.random.default_rng(seed)
    reg = MetricsRegistry()
    b = MicroBatcher(
        BatchingPolicy(max_batch=4, max_wait_ms=5.0, queue_limit=6),
        registry=reg,
    )
    now, rid = 0.0, 0
    for _ in range(int(rng.integers(10, 60))):
        now += float(rng.exponential(2.0))
        if rng.random() < 0.7:
            deadline = (
                now + float(rng.uniform(0.0, 10.0))
                if rng.random() < 0.5 else None
            )
            b.offer(LiveRequest(rid=rid, text="q", t_ms=now,
                                deadline_ms=deadline), now)
            rid += 1
        else:
            b.take(now)
            b.take_expired()
    b.check_accounting()
    assert reg.value("serving_offered_total") == b.n_offered
    assert reg.value("serving_routed_total") == b.n_taken
    assert reg.value("serving_shed_total") == b.n_shed
    assert reg.value("serving_expired_total") == b.n_expired
    assert reg.value("serving_queue_depth") == b.n_pending
    assert reg.value("serving_offered_total") == (
        reg.value("serving_routed_total") + reg.value("serving_shed_total")
        + reg.value("serving_expired_total")
        + reg.value("serving_queue_depth")
    )


def test_pump_registry_matches_report(pump_run):
    obs, _, _, rep = pump_run
    reg = obs.registry
    assert reg.value("serving_offered_total") == rep.n_offered
    assert reg.value("serving_routed_total") == rep.n_routed
    assert reg.value("serving_shed_total") == rep.n_shed
    assert reg.value("serving_expired_total") == rep.n_expired
    assert reg.value("serving_flushes_total") == rep.n_flushes
    assert reg.get("serving_latency_ms").count == rep.n_routed


def test_gateway_report_reads_the_shared_registry(pump_run):
    obs, gw, _, rep = pump_run
    report = gw.report()
    assert report["n"] == rep.n_routed
    assert report["shed"] == rep.n_shed
    assert report["expired"] == rep.n_expired
    assert report["n"] == obs.registry.get("gateway_latency_ms").count
    assert report["in_flight"] == 0.0


def test_registry_bind_semantics(tmp_path):
    reg = MetricsRegistry()
    c1 = reg.counter("x_total", "req")
    c2 = reg.counter("x_total")
    assert c1 is c2
    with pytest.raises(TypeError):
        reg.gauge("x_total")
    reg.gauge("depth").set(3)
    reg.histogram("lat_ms").observe(12.5)
    snap = reg.snapshot()
    assert snap["x_total"]["type"] == "counter"
    assert snap["depth"]["type"] == "gauge"
    assert snap["lat_ms"]["type"] == "histogram"
    for key in ("count", "mean", "p50", "p99", "p999"):
        assert key in snap["lat_ms"]
    path = tmp_path / "metrics.json"
    reg.to_json(str(path), extra={"summary": {"ok": True}})
    payload = json.loads(path.read_text())
    assert payload["metrics"].keys() == snap.keys()
    assert payload["summary"] == {"ok": True}


def test_histogram_quantiles_within_bucket_bound():
    rng = np.random.default_rng(0)
    vals = rng.lognormal(mean=3.0, sigma=1.0, size=5000)
    h = Histogram("lat", "ms")
    h.observe_many(vals)
    ratio = 10.0 ** (1.0 / h.per_decade)      # one-bucket relative width
    assert h.count == vals.size
    assert np.isclose(h.mean, vals.mean())
    assert h.vmin == vals.min() and h.vmax == vals.max()
    for q in (0.50, 0.99, 0.999):
        exact = float(np.percentile(vals, 100.0 * q))
        got = h.quantile(q)
        assert exact / ratio <= got <= exact * ratio
        assert h.vmin <= got <= h.vmax
    empty = Histogram("none")
    assert empty.quantile(0.99) == 0.0
    assert empty.snapshot()["min"] == 0.0 and empty.snapshot()["max"] == 0.0
    # out-of-range observations land in the edge buckets, never lost
    h2 = Histogram("edge", lo=1.0, hi=10.0, per_decade=4)
    h2.observe_many([0.01, 0.5, 50.0, 1e9])
    assert h2.count == 4 and sum(h2.counts) == 4


# ---------------------------------------------------------------------------
# DeviceRouteStats: padding exclusion + deferred drain
# ---------------------------------------------------------------------------

def test_device_route_stats_excludes_padding_and_defers():
    import jax.numpy as jnp

    drs = DeviceRouteStats(4)
    idx = jnp.asarray([2, 2, 1, 3], jnp.int32)
    c = jnp.asarray([0.5, 0.7, 0.9, 99.0], jnp.float32)
    n = jnp.asarray([0.2, 0.4, 0.6, 99.0], jnp.float32)
    s = jnp.asarray([0.6, 0.8, 1.0, 99.0], jnp.float32)
    drs.accumulate(idx, c, n, s, n_real=3)      # last row is padding
    assert len(drs._pending) == 1               # O(1) append, no dispatch
    out = drs.fold(reset=False)
    assert len(drs._pending) == 0
    np.testing.assert_array_equal(out["picks"], [0.0, 1.0, 2.0, 0.0])
    assert out["n_routed"] == 3.0
    assert np.isclose(out["mean_expertise"], (0.5 + 0.7 + 0.9) / 3)
    assert np.isclose(out["mean_network"], (0.2 + 0.4 + 0.6) / 3)
    assert np.isclose(out["mean_fused"], (0.6 + 0.8 + 1.0) / 3)
    # reset=True zeroes the device buffer
    drs.fold(reset=True)
    assert drs.fold(reset=False)["n_routed"] == 0.0
    # n_real=None counts every row
    drs.accumulate(idx, c, n, s)
    assert drs.fold()["n_routed"] == 4.0


def test_device_route_stats_max_pending_backstop():
    import jax.numpy as jnp

    drs = DeviceRouteStats(2)
    drs.MAX_PENDING = 2                         # shrink the inline bound
    one = jnp.asarray([1], jnp.int32)
    f = jnp.asarray([1.0], jnp.float32)
    drs.accumulate(one, f, f, f)
    assert len(drs._pending) == 1
    drs.accumulate(one, f, f, f)                # hits the backstop: drains
    assert len(drs._pending) == 0
    assert drs.fold()["picks"][1] == 2.0


def test_pump_route_stats_count_real_rows_only(pump_run):
    """Device-side pick counts equal the host-side routed count even
    though every flush was padded (the n_real mask excludes pad rows)."""
    obs, _, _, rep = pump_run
    stats = obs.route_stats.fold(reset=False)
    assert stats["n_routed"] == rep.n_routed
    assert stats["picks"].sum() == rep.n_routed


def test_observability_bundle_toggles():
    off = Observability()
    assert not off.tracer.enabled and off.route_stats is None
    assert off.ensure_route_stats(8) is None
    off.drain_route_stats()                     # no-op without stats
    assert off.fold_route_stats() is None
    on = Observability(jit_stats=True, audit=True)
    drs = on.ensure_route_stats(8)
    assert drs is not None and drs.n_servers == 8
    assert on.ensure_route_stats(8) is drs      # cached per fleet size
    assert on.ensure_route_stats(16) is not drs
    assert on.audit_tap is not None


# ---------------------------------------------------------------------------
# Audit tap: bit-exact score recomposition (all algorithms)
# ---------------------------------------------------------------------------

def _audit_fixture(seed, mask_kind, n_servers=5):
    rng = np.random.default_rng(seed)
    pick = rng.choice(len(POOL), size=n_servers, replace=False)
    servers = [POOL[i] for i in pick]
    hist = rng.uniform(5.0, 400.0, (n_servers, 24)).astype(np.float32)
    hist[rng.random(n_servers) < 0.3, -1] = OFFLINE_MS + 50.0
    load = (rng.random(n_servers) * 2.0).astype(np.float32)
    age = (rng.random(n_servers) * 600.0).astype(np.float32)
    if mask_kind == "none":
        mask = None
    elif mask_kind == "all":
        mask = np.ones(n_servers, bool)
    else:
        mask = rng.random(n_servers) < 0.4
    rtt = (rng.random(n_servers) * 500.0).astype(np.float32)
    return servers, hist, load, age, mask, rtt


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    algo=st.sampled_from(ALGOS),
    mask_kind=st.sampled_from(["none", "some", "all"]),
)
def test_audit_recomposition_is_bit_exact(seed, algo, mask_kind):
    """`ScoreAudit.recompose()` rebuilds the exact score vector the argmax
    saw, and `winning_score()` equals `Decision.fused` with no tolerance —
    for every algorithm, on the parity-suite style fixtures."""
    servers, hist, load, age, mask, rtt = _audit_fixture(seed, mask_kind)
    router = routing.make_router(
        algo, servers, RoutingConfig(top_s=4, top_k=5)
    )
    tap = AuditTap()
    for q in TEXTS[:2]:
        d = router.select(
            q, hist, load, telemetry_age_s=age, failed_mask=mask,
            client_rtt_ms=rtt, audit=tap,
        )
        a = tap.last
        assert a is not None and a.algo == router.name
        assert (a.server_idx, a.tool_idx) == (d.server_idx, d.tool_idx)
        assert a.winning_score() == d.fused
        np.testing.assert_array_equal(a.recompose(), a.fused)
        terms = a.terms()
        assert set(terms) == {"expertise", "network", "load", "rtt"}
        total = sum(terms.values())
        if np.isfinite(d.fused):
            assert np.isclose(total, d.fused, rtol=1e-5, atol=1e-6)
        assert router.name in a.explain()
    assert len(tap.records) == 2


def test_audit_records_every_failover_hop():
    servers = replica_fleet(4)
    router = routing.make_router(
        "sonar_ft", servers, RoutingConfig(top_s=4, top_k=4)
    )
    rng = np.random.default_rng(0)
    hist = rng.uniform(5.0, 200.0, (4, 16)).astype(np.float32)
    tap = AuditTap()
    d, hops = router.select_failover(
        TEXTS[0], hist, np.zeros(4, np.float32),
        alive=np.zeros(4, bool), budget=2, audit=tap,
    )
    assert hops == 2 and len(tap.records) == 3
    # consecutive hops mask out the previous pick
    picked = [r.server_idx for r in tap.records]
    assert len(set(picked)) == 3
    for r in tap.records:
        assert r.winning_score() == r.fused[r.best]


def test_audit_tap_is_bounded():
    tap = AuditTap(max_records=2)
    servers = replica_fleet(3)
    router = routing.make_router(
        "sonar", servers, RoutingConfig(top_s=3, top_k=3)
    )
    hist = np.full((3, 8), 50.0, np.float32)
    for _ in range(4):
        router.select(TEXTS[0], hist, audit=tap)
    assert len(tap.records) == 2 and tap.n_dropped == 2
    tap.clear()
    assert tap.records == [] and tap.n_dropped == 0


def test_gateway_threads_audit_tap():
    obs = Observability(audit=True)
    gw = SonarGateway(
        replica_pool([("yi-6b", "dense")] * 3), algo="sonar", obs=obs
    )
    gw.route(TEXTS[0])
    a = obs.audit_tap.last
    assert a is not None
    np.testing.assert_array_equal(a.recompose(), a.fused)


# ---------------------------------------------------------------------------
# Simulator + chaos trace integration
# ---------------------------------------------------------------------------

def test_simulator_metrics_and_chaos_trace():
    n, horizon = 4, 120.0
    sched = build_schedule(
        standard_fault_mix(0.8, n, horizon), n, int(horizon), 1.0, seed=0
    )
    plat = NetMCPPlatform(
        replica_fleet(n),
        profiles=[latlib.ideal_profile() for _ in range(n)],
        scenario="ideal", seed=0, horizon_s=horizon, dt_s=1.0, chaos=sched,
    )
    obs = Observability(trace=True)
    sim = FleetTrafficSim(
        plat,
        routing.make_router("sonar_ft", plat.servers,
                            RoutingConfig(top_s=n, top_k=n)),
        QueueConfig(capacity=4, queue_limit=16, base_service_ms=200.0),
        retry_budget=2, seed=1, obs=obs,
    )
    arr = poisson_arrivals(jax.random.PRNGKey(0), 2.0, horizon)
    rep = sim.run(arr, TEXTS)
    reg = obs.registry
    assert reg.value("sim_offered_total") == rep.n_offered
    assert reg.value("sim_completed_total") == rep.n_completed
    assert reg.value("sim_failed_total") == rep.n_failed
    assert reg.value("sim_drops_total") == rep.n_drop_events
    assert reg.value("sim_hedges_total") == rep.n_hedges
    names = [e["name"] for e in obs.tracer.events if e["ph"] == "i"]
    assert reg.value("sim_crashes_total") == names.count("crash")
    assert reg.value("sim_drops_total") == names.count("drop")
    events = obs.tracer.events
    assert len(_spans(events, "serve")) == rep.n_completed
    # the fault schedule is rendered onto the chaos track
    assert sched.down.any()
    assert _spans(events, "down")
    assert any(
        e["name"] == "inject:down" and e["pid"] == "chaos" for e in events
    )
    _assert_valid_chrome_trace(obs.tracer.to_chrome_trace())


# ---------------------------------------------------------------------------
# Dashboard
# ---------------------------------------------------------------------------

def test_render_dashboard_panel(pump_run):
    obs, _, _, rep = pump_run
    stats = obs.route_stats.fold(reset=False)
    panel = render_dashboard(
        obs.registry.snapshot(), stats, title="obs test"
    )
    assert "obs test" in panel
    assert "offered / routed" in panel
    assert f"{rep.n_offered:.0f} / {rep.n_routed:.0f}" in panel
    assert "serve p50 / p99 / p999" in panel
    assert "replica" in panel                    # pick distribution rows
    assert "mean C / N / S" in panel
    # every line fits the fixed box width
    widths = {len(line) for line in panel.splitlines()}
    assert len(widths) == 1


def test_live_dashboard_repaints_in_place(pump_run):
    obs, _, _, _ = pump_run
    out = io.StringIO()
    dash = LiveDashboard(
        obs.registry, route_stats_fn=None, min_interval_s=60.0,
        stream=out, title="live",
    )
    assert dash.update(force=True)
    assert not dash.update()                     # throttled
    assert dash.update(force=True)
    text = out.getvalue()
    assert "live" in text
    assert "\x1b[" in text                       # ANSI in-place repaint
