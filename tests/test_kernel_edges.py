"""Edge-case coverage for the routing Pallas kernels.

`kernels/select_fuse` and `kernels/qos_score` are exercised at the shape
and degeneracy boundaries the fleet benchmarks never hit: fleets that are
not a multiple of the kernel tile sizes, single-server fleets, fewer tools
than the requested top-k, and rows where every candidate is invalid or
masked.  Every case runs with ``interpret=True`` explicitly, so the suite
passes (and still measures kernel semantics) on backends without Pallas
Mosaic support.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.qos import DEFAULT_QOS, network_score
from repro.kernels import ops, ref
from repro.kernels.qos_score import SERVER_TILE
from repro.kernels.select_fuse import QUERY_TILE

NEG = ref.NEG


def _assert_select_matches(sel, val, qos, load=None, dead=None, **kw):
    kw.setdefault("alpha", 0.5)
    kw.setdefault("beta", 0.5)
    got = ops.fused_select(
        jnp.asarray(sel), jnp.asarray(val), jnp.asarray(qos),
        None if load is None else jnp.asarray(load),
        None if dead is None else jnp.asarray(dead),
        interpret=True, **kw,
    )
    want = ref.fused_select_ref(
        jnp.asarray(sel), jnp.asarray(val), jnp.asarray(qos),
        None if load is None else jnp.asarray(load),
        None if dead is None else jnp.asarray(dead),
        **kw,
    )
    assert (np.asarray(got[0]) == np.asarray(want[0])).all(), "tool_idx"
    for name, g, w in zip(("C", "N", "S"), got[1:], want[1:]):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=1e-5, atol=1e-6, err_msg=name
        )
    return got


# ---------------------------------------------------------------------------
# fused_select edge cases
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_q,n_t", [
    (1, 1),        # single query, single tool
    (3, 130),      # tools just past one 128 lane; queries not a tile multiple
    (QUERY_TILE + 1, 300),   # queries one past the tile; tools 2x128+44
    (2, 127),      # tools one short of the lane boundary
])
def test_fused_select_off_tile_shapes(n_q, n_t):
    rng = np.random.default_rng(n_q * 1000 + n_t)
    sel = rng.standard_normal((n_q, n_t)).astype(np.float32)
    qos = rng.random((n_t,)).astype(np.float32) * 2 - 1
    _assert_select_matches(sel, sel, qos, k=min(8, n_t))


def test_fused_select_single_server_fleet():
    """One server, one tool: the only candidate must win with C == 1."""
    sel = np.asarray([[2.5], [0.1], [-1.0]], np.float32)
    qos = np.asarray([0.3], np.float32)
    idx, c, n, s = _assert_select_matches(sel, sel, qos, k=4)
    assert (np.asarray(idx) == 0).all()
    np.testing.assert_allclose(np.asarray(c), 1.0, rtol=1e-6)


def test_fused_select_fewer_tools_than_k():
    """k > n_tools: every tool is a candidate; no phantom candidates from
    the padding lanes may enter the softmax or the argmax."""
    rng = np.random.default_rng(0)
    n_q, n_t = 5, 7
    sel = rng.standard_normal((n_q, n_t)).astype(np.float32)
    qos = rng.random((n_q, n_t)).astype(np.float32)
    idx, c, *_ = _assert_select_matches(sel, sel, qos, k=10)
    # softmax mass sums to one over the n_t real candidates only
    full = ref.fused_select_ref(
        jnp.asarray(sel), jnp.asarray(sel), jnp.asarray(qos), k=n_t,
        alpha=0.5, beta=0.5,
    )
    assert (np.asarray(idx) == np.asarray(full[0])).all()


def test_fused_select_all_candidates_invalid():
    """Rows whose stage-2 scores are all -inf (no tool on any candidate
    server): every path returns the first (rank-0) candidate, mirroring
    np.argmax over an all--inf score vector."""
    n_q, n_t = 4, 40
    sel = np.full((n_q, n_t), -np.inf, np.float32)
    qos = np.zeros((n_t,), np.float32)
    idx, c, n, s = _assert_select_matches(sel, sel, qos, k=8)
    assert (np.asarray(idx) == 0).all()
    assert (np.asarray(s) <= NEG / 2.0).all()   # fused score flags no winner


def test_fused_select_all_candidates_dead():
    """A fault mask covering the entire fleet: decisions still come back
    (the top-selection candidate) and match the oracle and the scalar
    np.argmax semantics."""
    rng = np.random.default_rng(3)
    n_q, n_t = 6, 90
    sel = rng.standard_normal((n_q, n_t)).astype(np.float32) * 2
    qos = rng.random((n_t,)).astype(np.float32)
    dead = np.ones((n_t,), np.float32)
    idx, c, n, s = _assert_select_matches(sel, sel, qos, dead=dead, k=6)
    top1 = np.argmax(sel, axis=1)
    assert (np.asarray(idx) == top1).all()
    assert (np.asarray(s) <= NEG / 2.0).all()


def test_fused_select_mixed_dead_and_invalid():
    rng = np.random.default_rng(9)
    n_q, n_t = 9, 150
    sel = rng.standard_normal((n_q, n_t)).astype(np.float32) * 3
    sel = np.where(rng.random((n_q, n_t)) < 0.5, sel, -np.inf)
    qos = (rng.random((n_q, n_t)).astype(np.float32)) * 2 - 1
    load = rng.random((n_t,)).astype(np.float32)
    dead = (rng.random((n_q, n_t)) < 0.5).astype(np.float32)
    _assert_select_matches(
        sel, sel, qos, load=load, dead=dead, k=12, gamma=0.3
    )


# ---------------------------------------------------------------------------
# qos_score edge cases
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_servers", [
    1,                       # single-server fleet
    SERVER_TILE - 1,         # one short of the tile
    SERVER_TILE + 44,        # not a multiple of the tile
])
@pytest.mark.parametrize("T", [5, 50, 128])
def test_qos_kernel_off_tile_fleets(n_servers, T):
    rng = np.random.default_rng(n_servers * 7 + T)
    lat = rng.uniform(5.0, 900.0, size=(n_servers, T)).astype(np.float32)
    lat[rng.random(n_servers) < 0.2, -1] = 1200.0       # some offline
    got = np.asarray(ops.qos_scores(jnp.asarray(lat), interpret=True))
    want = np.asarray(network_score(jnp.asarray(lat), DEFAULT_QOS))
    assert got.shape == (n_servers,)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_qos_kernel_single_sample_history():
    """T == 1: the EWMA carry, trend and CV windows all degenerate."""
    lat = np.asarray([[30.0], [400.0], [1200.0]], np.float32)
    got = np.asarray(ops.qos_scores(jnp.asarray(lat), interpret=True))
    want = np.asarray(network_score(jnp.asarray(lat), DEFAULT_QOS))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)
    assert got[2] == -1.0                                # offline clamp
