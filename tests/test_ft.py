"""Fault tolerance: checkpoint roundtrip, failure injection, SONAR
straggler mitigation, elastic planning."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ft import checkpoint as ckpt
from repro.ft.failure import FailureInjector, FleetMonitor, plan_elastic


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "layer": {"w": jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)},
        "step": jnp.asarray(7, jnp.int32),
        "scale": (jnp.asarray(1.5), jnp.asarray([2.0, 3.0], jnp.bfloat16)),
    }


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 10, tree, {"next_step": 11})
    restored, extras = ckpt.restore(str(tmp_path), 10, tree)
    assert extras["next_step"] == 11
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_latest_step_and_overwrite(tmp_path):
    assert ckpt.latest_step(str(tmp_path)) is None
    ckpt.save(str(tmp_path), 5, _tree(0))
    ckpt.save(str(tmp_path), 20, _tree(1))
    assert ckpt.latest_step(str(tmp_path)) == 20
    ckpt.save(str(tmp_path), 20, _tree(2))  # idempotent overwrite
    assert ckpt.latest_step(str(tmp_path)) == 20


def test_incomplete_checkpoint_ignored(tmp_path):
    os.makedirs(tmp_path / "step_99")  # no manifest -> incomplete
    ckpt.save(str(tmp_path), 3, _tree())
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_monitor_flags_crash():
    mon = FleetMonitor(n_pods=4, base_step_s=1.0)
    inj = FailureInjector(4, base_step_s=1.0)
    inj.crash(2)
    for _ in range(8):
        mon.record(inj.step_times())
    scores = mon.scores()
    assert scores[2] == -1.0
    assert 2 not in mon.healthy_pods()
    assert set(mon.healthy_pods()) >= {0, 1, 3}


def test_monitor_flags_straggler():
    mon = FleetMonitor(n_pods=4, base_step_s=1.0)
    inj = FailureInjector(4, base_step_s=1.0, seed=1)
    inj.straggle(1, factor=8.0)
    for _ in range(20):
        mon.record(inj.step_times())
    assert 1 not in mon.healthy_pods()


def test_elastic_plan_rescales_batch():
    mon = FleetMonitor(n_pods=4, base_step_s=1.0)
    inj = FailureInjector(4, base_step_s=1.0)
    inj.crash(0)
    for _ in range(8):
        mon.record(inj.step_times())
    plan = plan_elastic(mon, global_batch=256, prev_healthy=[0, 1, 2, 3])
    assert plan.changed and plan.n_pods == 3
    assert plan.per_pod_batch == 85


def test_healed_pod_rejoins():
    mon = FleetMonitor(n_pods=2, base_step_s=1.0, history=16)
    inj = FailureInjector(2, base_step_s=1.0)
    inj.crash(1)
    for _ in range(6):
        mon.record(inj.step_times())
    assert 1 not in mon.healthy_pods()
    inj.heal(1)
    for _ in range(30):
        mon.record(inj.step_times())
    assert 1 in mon.healthy_pods()


def test_never_empty_fleet():
    mon = FleetMonitor(n_pods=2, base_step_s=1.0)
    inj = FailureInjector(2, base_step_s=1.0)
    inj.crash(0)
    inj.crash(1)
    for _ in range(8):
        mon.record(inj.step_times())
    plan = plan_elastic(mon, global_batch=64)
    assert plan.n_pods >= 1


def test_train_loop_restart_resumes(tmp_path):
    """End-to-end: crash mid-run, restart from checkpoint, step counter resumes."""
    from repro import configs
    from repro.launch.train import train_loop

    cfg = configs.get_reduced("xlstm-125m")
    train_loop(cfg, steps=6, global_batch=2, seq_len=16,
               ckpt_dir=str(tmp_path), ckpt_every=3)
    assert ckpt.latest_step(str(tmp_path)) == 6
    # "restart": a fresh loop must resume from 6, not retrain
    losses = train_loop(cfg, steps=8, global_batch=2, seq_len=16,
                        ckpt_dir=str(tmp_path), ckpt_every=3)
    assert len(losses) == 2  # only steps 6,7 ran
