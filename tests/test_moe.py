"""Property tests for the sort-based capacity MoE dispatch invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.models import mlp
from repro.models.config import ModelConfig
from repro.nn.core import InitCtx, unzip


def _cfg(E=8, K=2, shared=0, cf=1.25):
    return ModelConfig(
        name="t", family="moe", n_layers=1, d_model=32, n_heads=4, n_kv_heads=4,
        d_ff=64, vocab_size=128, n_experts=E, experts_per_token=K,
        n_shared_experts=shared, moe_d_ff=16, capacity_factor=cf,
        dtype="float32",
    )


def _params(cfg, seed=0):
    p, _ = unzip(mlp.moe_ffn_init(InitCtx(key=jax.random.PRNGKey(seed), dtype=jnp.float32), cfg))
    return p


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**20), T=st.integers(4, 40), E=st.sampled_from([4, 8]),
       K=st.sampled_from([1, 2]))
def test_dispatch_invariants(seed, T, E, K):
    cfg = _cfg(E=E, K=K)
    p = _params(cfg, seed % 7)
    rng = np.random.default_rng(seed)
    xf = jnp.asarray(rng.standard_normal((T, cfg.d_model)), jnp.float32)
    C = mlp._capacity(T, K, E, cfg.capacity_factor)
    buf, slot, token_of, w_keep, aux = mlp._moe_dispatch(p, cfg, xf, C)
    # shapes + ranges
    assert buf.shape == (E, C, cfg.d_model)
    assert ((slot >= 0) & (slot < E * C)).all()
    assert ((token_of >= 0) & (token_of < T)).all()
    # combine weights: non-negative, per-token total <= 1 (+eps)
    w = np.zeros(T)
    np.add.at(w, np.asarray(token_of), np.asarray(w_keep))
    assert (np.asarray(w_keep) >= 0).all()
    assert (w <= 1.0 + 1e-5).all()
    # per-expert occupancy never exceeds capacity
    kept = np.asarray(w_keep) > 0
    experts_of_slot = np.asarray(slot)[kept] // C
    occup = np.bincount(experts_of_slot, minlength=E)
    assert (occup <= C).all()
    assert np.isfinite(float(aux))


def test_no_drops_at_high_capacity():
    cfg = _cfg(E=4, K=2, cf=8.0)
    p = _params(cfg)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)), jnp.float32)
    C = mlp._capacity(16, 2, 4, 8.0)
    _, _, _, w_keep, _ = mlp._moe_dispatch(p, cfg, x[0], C)
    # every (token, expert) assignment kept -> per-token weights sum to 1
    w = np.zeros(16)
    np.add.at(w, np.arange(16).repeat(2), np.ones(32) * 0)  # placeholder
    buf, slot, token_of, w_keep, _ = mlp._moe_dispatch(p, cfg, x[0], C)
    tot = np.zeros(16)
    np.add.at(tot, np.asarray(token_of), np.asarray(w_keep))
    np.testing.assert_allclose(tot, 1.0, rtol=1e-5)


def test_moe_matches_dense_when_one_expert():
    """E=1, K=1, no drops: MoE == a single dense expert FFN."""
    cfg = _cfg(E=1, K=1, cf=4.0)
    p = _params(cfg)
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)), jnp.float32)
    y, aux = mlp.moe_ffn_apply(p, cfg, x)
    # reference: run the single expert densely
    w1, w2, w3 = p["w_gate"][0], p["w_up"][0], p["w_down"][0]
    ref = jnp.einsum(
        "bsf,fd->bsd",
        jax.nn.silu(jnp.einsum("bsd,df->bsf", x, w1)) * jnp.einsum("bsd,df->bsf", x, w2),
        w3,
    )
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_shared_experts_always_active():
    cfg = _cfg(E=4, K=1, shared=2)
    p = _params(cfg)
    x = jnp.zeros((1, 4, cfg.d_model), jnp.float32)
    y0, _ = mlp.moe_ffn_apply(p, cfg, x)
    x1 = jnp.ones((1, 4, cfg.d_model), jnp.float32)
    y1, _ = mlp.moe_ffn_apply(p, cfg, x1)
    assert not np.allclose(np.asarray(y0), np.asarray(y1))
