"""Batched routing engine: scalar parity, fused-selection kernel equivalence,
the vectorized episode driver, and the extended scenario registry."""
import numpy as np
import pytest

from repro.core import agent, dataset, latency as L, metrics, platform, routing
from repro.core.batch_routing import make_engine
from repro.core.routing import RoutingConfig
from repro.kernels import ops, ref

SERVERS = dataset.build_server_pool(seed=0)
QUERY_TEXTS = [q.text for q in dataset.build_query_dataset(n=64, seed=1)]
ALL_SCENARIOS = list(platform.SCENARIOS)
# sonar_lb with no server_load supplied must collapse to sonar exactly —
# including it here asserts the load term is a pure extension
ALGOS = ["rag", "rerank_rag", "prag", "sonar", "sonar_lb"]


# ---------------------------------------------------------------------------
# Fused selection kernel vs pure-jnp oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_q,n_t,k,per_q,rerank", [
    (5, 30, 10, False, False),
    (64, 300, 12, True, False),
    (8, 40, 6, False, True),
    (3, 7, 10, True, False),     # k > n_tools
    (130, 200, 5, True, False),  # query padding
])
def test_fused_select_kernel_matches_oracle(n_q, n_t, k, per_q, rerank):
    import jax.numpy as jnp

    rng = np.random.default_rng(n_q * 100 + n_t)
    sel = rng.standard_normal((n_q, n_t)).astype(np.float32) * 3
    sel = np.where(rng.random((n_q, n_t)) < 0.4, sel, -np.inf)
    val = (
        rng.standard_normal((n_q, n_t)).astype(np.float32) if rerank else sel
    )
    qos = (rng.random((n_q, n_t) if per_q else (n_t,)).astype(np.float32)) * 2 - 1
    got = ops.fused_select(
        jnp.asarray(sel), jnp.asarray(val), jnp.asarray(qos),
        k=k, alpha=0.5, beta=0.5,
    )
    want = ref.fused_select_ref(
        jnp.asarray(sel), jnp.asarray(val), jnp.asarray(qos),
        k=k, alpha=0.5, beta=0.5,
    )
    assert (np.asarray(got[0]) == np.asarray(want[0])).all()
    for g, w in zip(got[1:], want[1:]):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Batched engine == scalar Router.select (argmax-identical)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scenario", ALL_SCENARIOS)
@pytest.mark.parametrize("algo", ALGOS)
def test_batched_matches_scalar(scenario, algo):
    plat = platform.NetMCPPlatform(SERVERS, scenario=scenario, seed=1)
    hist = plat.latency_window(3000)
    router = routing.make_router(algo, SERVERS)
    engine = make_engine(algo, SERVERS)
    dec = engine.route_texts(QUERY_TEXTS, hist)
    for i, q in enumerate(QUERY_TEXTS):
        d = router.select(q, hist)
        assert (d.server_idx, d.tool_idx) == (
            int(dec.server_idx[i]), int(dec.tool_idx[i])
        ), f"{scenario}/{algo} query {i}"


def test_batched_kernel_path_matches_scalar():
    """The Pallas path (interpret mode on CPU) is selection-identical too."""
    plat = platform.NetMCPPlatform(SERVERS, scenario="hybrid", seed=1)
    hist = plat.latency_window(3000)
    router = routing.make_router("sonar", SERVERS)
    engine = make_engine("sonar", SERVERS, use_kernels=True)
    dec = engine.route_texts(QUERY_TEXTS, hist)
    for i, q in enumerate(QUERY_TEXTS):
        d = router.select(q, hist)
        assert (d.server_idx, d.tool_idx) == (
            int(dec.server_idx[i]), int(dec.tool_idx[i])
        )


def test_batched_respects_config_and_exposes_scores():
    cfg = RoutingConfig(top_s=3, top_k=6, alpha=0.7, beta=0.3)
    plat = platform.NetMCPPlatform(SERVERS, scenario="fluctuating", seed=2)
    hist = plat.latency_window(2000)
    router = routing.make_router("sonar", SERVERS, cfg)
    engine = make_engine("sonar", SERVERS, cfg)
    dec = engine.route_texts(QUERY_TEXTS[:16], hist)
    for i, q in enumerate(QUERY_TEXTS[:16]):
        d = router.select(q, hist)
        assert d.server_idx == int(dec.server_idx[i])
        np.testing.assert_allclose(d.expertise, dec.expertise[i], rtol=1e-4)
        np.testing.assert_allclose(d.fused, dec.fused[i], rtol=1e-4, atol=1e-5)
    assert dec.select_latency_ms == pytest.approx(
        routing.LLM_CALL_MS + 2 * routing.BM25_STAGE_MS
    )


def test_per_query_telemetry_routes_per_time():
    """3-D telemetry: each query is scored against its own latency window."""
    plat = platform.NetMCPPlatform(SERVERS, scenario="hybrid", seed=1)
    t_vec = np.asarray([100, 2000, 4000, 6000])
    windows = plat.latency_windows(t_vec)
    assert windows.shape == (4, len(SERVERS), plat.history_window)
    for i, t in enumerate(t_vec):
        np.testing.assert_array_equal(windows[i], plat.latency_window(int(t)))
    engine = make_engine("sonar", SERVERS)
    router = routing.make_router("sonar", SERVERS)
    q = QUERY_TEXTS[0]
    dec = engine.route_texts([q] * len(t_vec), windows)
    for i, t in enumerate(t_vec):
        d = router.select(q, plat.latency_window(int(t)))
        assert d.server_idx == int(dec.server_idx[i])


# ---------------------------------------------------------------------------
# Vectorized episode driver
# ---------------------------------------------------------------------------

def test_batch_agent_matches_scalar_agent():
    queries = dataset.build_query_dataset(n=60, seed=0)
    for scenario in ("hybrid", "fluctuating"):
        p1 = platform.NetMCPPlatform(SERVERS, scenario=scenario, seed=1)
        r = routing.make_router("sonar", SERVERS)
        recs1 = agent.Agent(p1, r).run_benchmark(queries, ticks_per_query=60)
        p2 = platform.NetMCPPlatform(SERVERS, scenario=scenario, seed=1)
        recs2 = agent.BatchAgent(p2, make_engine("sonar", SERVERS)).run_benchmark(
            queries, ticks_per_query=60
        )
        for a, b in zip(recs1, recs2):
            assert a.final_server_idx == b.final_server_idx
            assert a.n_calls == b.n_calls
            assert a.success == b.success
            assert a.n_failures == b.n_failures
            assert a.completion_ms == pytest.approx(b.completion_ms, rel=1e-4)
        m1 = metrics.evaluate(recs1, SERVERS)
        m2 = metrics.evaluate(recs2, SERVERS)
        assert m1.ssr == m2.ssr and m1.fr == m2.fr


def test_batch_agent_table2_headline():
    """The batched driver reproduces the Table II headline (SONAR 0% FR)."""
    queries = dataset.build_query_dataset(n=60, seed=0)
    plat = platform.NetMCPPlatform(SERVERS, scenario="hybrid", seed=1)
    recs = agent.BatchAgent(plat, make_engine("sonar", SERVERS)).run_benchmark(
        queries, ticks_per_query=60
    )
    rep = metrics.evaluate(recs, SERVERS)
    assert rep.fr == 0.0 and rep.al_ms < 50.0


# ---------------------------------------------------------------------------
# Scenario registry (all five canonical states + composed)
# ---------------------------------------------------------------------------

def test_scenario_registry_covers_paper_states():
    assert set(platform.SCENARIOS) >= {
        "ideal", "hybrid", "fluctuating",
        "high_latency", "high_jitter", "diurnal_congestion",
    }


def test_high_latency_scenario_profile_classes():
    profs = platform.SCENARIOS["high_latency"](SERVERS)
    ws = [p for s, p in zip(SERVERS, profs) if s.domain == dataset.WEBSEARCH]
    hl = L.high_latency_profile()
    elevated = [p for p in ws if p.base_latency_ms == hl.base_latency_ms]
    assert len(elevated) == len(ws) - 1          # one ideal escape hatch
    assert sum(p.base_latency_ms <= 50.0 for p in ws) == 1
    for s, p in zip(SERVERS, profs):
        if s.domain != dataset.WEBSEARCH:
            assert p.base_latency_ms < hl.base_latency_ms


def test_high_jitter_scenario_profile_classes():
    profs = platform.SCENARIOS["high_jitter"](SERVERS)
    for s, p in zip(SERVERS, profs):
        if s.domain == dataset.WEBSEARCH:
            assert p.std_dev_ms >= 70.0          # high-jitter canonical state
            assert p.base_latency_ms == 100.0
        else:
            assert p.std_dev_ms <= 10.0


def test_diurnal_congestion_composes_states():
    profs = platform.SCENARIOS["diurnal_congestion"](SERVERS)
    ws = [p for s, p in zip(SERVERS, profs) if s.domain == dataset.WEBSEARCH]
    assert all(p.amplitude_ms > 0 for p in ws)               # diurnal rhythm
    assert all(p.period_s == 24 * 3600.0 for p in ws)
    assert sum(p.outage_probability > 0 for p in ws) == 1    # congested top
    phases = sorted(p.phase_shift for p in ws)
    assert len(set(phases)) == len(ws)                       # staggered


def test_new_scenarios_route_end_to_end():
    """SONAR beats PRAG on latency in both new single-state scenarios."""
    queries = dataset.build_query_dataset(n=40, seed=0)
    for scenario in ("high_latency", "high_jitter"):
        reports = {}
        for algo in ("prag", "sonar"):
            plat = platform.NetMCPPlatform(SERVERS, scenario=scenario, seed=3)
            recs = agent.BatchAgent(plat, make_engine(algo, SERVERS)).run_benchmark(
                queries, ticks_per_query=60
            )
            reports[algo] = metrics.evaluate(recs, SERVERS)
        assert reports["sonar"].al_ms < reports["prag"].al_ms, scenario
        assert abs(reports["sonar"].ssr - reports["prag"].ssr) < 15.0
