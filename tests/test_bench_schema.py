"""Unit tests for the benchmark-artifact schema validator
(tools/check_bench_schema.py) and the schema-validated writer
(benchmarks/common.write_artifact)."""
import json
import pathlib
import sys

import pytest

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT))

from tools.check_bench_schema import (  # noqa: E402
    SCHEMAS,
    schema_name_for,
    validate_artifact,
)
from tools.check_bench_schema import main as schema_main  # noqa: E402

GOOD_GEO = {
    "replicas_per_region": 3,
    "rate_rps": 6.0,
    "horizon_s": 40.0,
    "base_service_ms": 150.0,
    "client_skew": 1.5,
    "points": [
        {
            "algo": "sonar_geo", "n_regions": 3, "rtt_scale": 3.0,
            "mean_cross_rtt_ms": 347.0, "rtt_dominant": True,
            "p50_ms": 157.0, "p99_ms": 866.0, "p99_tail_ms": 860.0,
            "goodput_rps": 4.5, "failed": 0, "local_share": 0.99,
        },
        {
            "algo": "sonar_lb", "n_regions": 3, "rtt_scale": 3.0,
            "mean_cross_rtt_ms": 347.0, "rtt_dominant": True,
            "p50_ms": 446.0, "p99_ms": 1238.0, "p99_tail_ms": 1230.0,
            "goodput_rps": 4.47, "failed": 0, "local_share": 0.35,
        },
    ],
}


def test_known_schemas_cover_all_artifacts():
    assert sorted(SCHEMAS) == [
        "adaptive-routing", "bench-results", "chaos-recovery", "geo-routing",
        "mega-fleet", "obs-overhead", "offered-load", "serve-metrics",
        "serve-trace", "serving-qps", "session-routing",
    ]
    assert schema_name_for("some/dir/geo-routing.json") == "geo-routing"
    assert schema_name_for("ci/adaptive-routing.json") == "adaptive-routing"
    # committed perf-trajectory baselines map to the plain schema names
    assert schema_name_for("BENCH_serving_qps.json") == "serving-qps"
    assert schema_name_for("repo/BENCH_mega_fleet.json") == "mega-fleet"
    assert schema_name_for("BENCH_obs_overhead.json") == "obs-overhead"
    assert schema_name_for("BENCH_session_routing.json") == "session-routing"
    assert schema_name_for("ci/serve-trace.json") == "serve-trace"
    assert schema_name_for("ci/serve-metrics.json") == "serve-metrics"


GOOD_SERVING = {
    "algo": "sonar_lb", "n_replicas": 4, "max_batch": 16,
    "max_wait_ms": 2.0, "queue_limit": 64, "horizon_s": 0.6,
    "oracle": {"oracle_qps": 5000.0, "oracle_p50_ms": 3.2,
               "oracle_p99_ms": 4.0, "n_batches": 16},
    "knee": None,
    "points": [
        {"rate_rps": 1000.0, "offered": 600, "routed": 600, "shed": 0,
         "expired": 0, "sustained_qps": 1300.0, "p50_ms": 2.3,
         "p99_ms": 3.6, "mean_batch": 3.2, "flushes": 180},
        {"rate_rps": 6500.0, "offered": 3900, "routed": 3000, "shed": 900,
         "expired": 0, "sustained_qps": 5100.0, "p50_ms": 13.0,
         "p99_ms": 21.0, "mean_batch": 15.9, "flushes": 190},
    ],
}


def test_serving_qps_schema_and_conservation():
    assert validate_artifact("serving-qps", GOOD_SERVING) == []
    bad = json.loads(json.dumps(GOOD_SERVING))
    bad["points"][1]["shed"] = 1          # breaks offered == routed+shed+expired
    errs = validate_artifact("serving-qps", bad)
    assert any("offered != routed + shed + expired" in e for e in errs)
    bad2 = json.loads(json.dumps(GOOD_SERVING))
    bad2["oracle"]["oracle_p99_ms"] = "fast"
    errs = validate_artifact("serving-qps", bad2)
    assert any("oracle_p99_ms" in e for e in errs)


def test_valid_geo_payload_passes():
    assert validate_artifact("geo-routing", GOOD_GEO) == []


GOOD_SESSION = {
    "n_replicas": 6,
    "queue": {"capacity": 4, "queue_limit": 16, "base_service_ms": 200.0},
    "horizon_s": 60.0,
    "points": [
        {"algo": "sonar", "session_rate": 9.0, "n_sessions": 540,
         "task_success_rate": 0.991, "task_p50_ms": 2229.0,
         "task_p99_ms": 5160.0, "task_mean_ms": 2400.0, "tasks_failed": 5,
         "nodes_offered": 2300, "nodes_completed": 2290, "nodes_failed": 10,
         "nodes_abandoned": 11, "n_hedges": 1494},
        {"algo": "sonar_session", "session_rate": 9.0, "n_sessions": 540,
         "task_success_rate": 1.0, "task_p50_ms": 793.0,
         "task_p99_ms": 2372.0, "task_mean_ms": 900.0, "tasks_failed": 0,
         "nodes_offered": 2311, "nodes_completed": 2311, "nodes_failed": 0,
         "nodes_abandoned": 0, "n_hedges": 1},
    ],
}


def test_session_routing_schema_and_node_conservation():
    assert validate_artifact("session-routing", GOOD_SESSION) == []
    bad = json.loads(json.dumps(GOOD_SESSION))
    bad["points"][0]["nodes_completed"] = 2289   # breaks offered == c + f
    errs = validate_artifact("session-routing", bad)
    assert any("nodes_offered != completed + failed" in e for e in errs)
    bad2 = json.loads(json.dumps(GOOD_SESSION))
    del bad2["points"][1]["task_p99_ms"]
    errs = validate_artifact("session-routing", bad2)
    assert any("task_p99_ms" in e for e in errs)


def test_missing_key_and_type_violations_are_reported():
    bad = {k: v for k, v in GOOD_GEO.items() if k != "rate_rps"}
    errs = validate_artifact("geo-routing", bad)
    assert any("rate_rps" in e for e in errs)

    bad2 = json.loads(json.dumps(GOOD_GEO))
    bad2["points"][0]["p99_ms"] = "fast"
    errs = validate_artifact("geo-routing", bad2)
    assert any("p99_ms" in e and "number" in e for e in errs)

    bad3 = json.loads(json.dumps(GOOD_GEO))
    del bad3["points"][1]["algo"]
    errs = validate_artifact("geo-routing", bad3)
    assert any("points[1]" in e and "algo" in e for e in errs)


def test_bool_is_not_a_number():
    bad = json.loads(json.dumps(GOOD_GEO))
    bad["rate_rps"] = True
    assert any("rate_rps" in e for e in validate_artifact("geo-routing", bad))


def test_unknown_schema_is_an_error():
    errs = validate_artifact("nonexistent", {})
    assert errs and "unknown artifact schema" in errs[0]


def test_mega_fleet_parity_gate():
    payload = {
        "config": {}, "parity": {"ok": False},
        "points": [{"algo": "sonar", "n_servers": 10, "n_shards": 2,
                    "us_per_query": 1.0, "routes_per_s": 10.0}],
    }
    errs = validate_artifact("mega-fleet", payload)
    assert any("parity.ok" in e for e in errs)
    payload["parity"]["ok"] = True
    assert validate_artifact("mega-fleet", payload) == []


def test_cli_exit_codes(tmp_path, capsys):
    good = tmp_path / "geo-routing.json"
    good.write_text(json.dumps(GOOD_GEO))
    assert schema_main([str(good)]) == 0
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"points": []}))
    assert schema_main([str(bad), "--schema", "geo-routing"]) == 1
    assert schema_main([str(tmp_path / "missing.json"),
                        "--schema", "geo-routing"]) == 1
    capsys.readouterr()


def test_write_artifact_validates(tmp_path):
    sys.path.insert(0, str(REPO_ROOT))
    from benchmarks.common import write_artifact

    out = tmp_path / "geo-routing.json"
    write_artifact(str(out), GOOD_GEO)
    assert json.loads(out.read_text())["rate_rps"] == 6.0
    with pytest.raises(ValueError, match="violates schema"):
        write_artifact(str(tmp_path / "geo-routing.json"), {"points": []})
