"""SONAR QoS scoring (Eq. 7) properties + Pallas kernel equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core.qos import DEFAULT_QOS, QosParams, ewma, network_score, penalties
from repro.kernels import ops


def test_ideal_band_scores_high():
    lat = jnp.full((3, 64), 30.0)
    n = np.asarray(network_score(lat))
    assert (n > 0.95).all()


def test_offline_clamp():
    lat = np.full((2, 64), 30.0, np.float32)
    lat[0, -1] = 1000.0
    n = np.asarray(network_score(jnp.asarray(lat)))
    assert n[0] == -1.0 and n[1] > 0.9


def test_high_latency_penalized_monotonically():
    scores = []
    for base in [30, 100, 300, 600]:
        lat = jnp.full((1, 64), float(base))
        scores.append(float(network_score(lat)[0]))
    assert all(a > b for a, b in zip(scores, scores[1:]))


def test_trend_penalty():
    flat = jnp.full((1, 64), 100.0)
    rising = jnp.asarray(np.linspace(50, 150, 64, dtype=np.float32))[None]
    assert float(network_score(rising)[0]) < float(network_score(flat)[0])


def test_outage_risk_penalty():
    calm = np.full((1, 64), 100.0, np.float32)
    risky = calm.copy()
    risky[0, -8:-1] = 900.0  # recent >800ms events (not offline at t)
    assert float(network_score(jnp.asarray(risky))[0]) < float(
        network_score(jnp.asarray(calm))[0]
    )


def test_instability_penalty():
    rng = np.random.default_rng(0)
    stable = np.full((1, 64), 100.0, np.float32)
    jittery = (100 + 60 * rng.standard_normal((1, 64))).astype(np.float32)
    jittery = np.clip(jittery, 1.0, 700.0)
    assert float(network_score(jnp.asarray(jittery))[0]) < float(
        network_score(jnp.asarray(stable))[0]
    )


@settings(max_examples=30, deadline=None)
@given(
    lat=hnp.arrays(
        np.float32,
        st.tuples(st.integers(1, 8), st.integers(4, 96)),
        elements=st.floats(1.0, 2000.0, width=32),
    )
)
def test_score_range_property(lat):
    n = np.asarray(network_score(jnp.asarray(lat)))
    assert ((n >= -1.0) & (n <= 1.0)).all()
    offline = lat[:, -1] >= 1000.0
    assert (n[offline] == -1.0).all()
    assert (n[~offline] >= 0.0).all()


def test_ewma_matches_recursive():
    rng = np.random.default_rng(1)
    lat = rng.random((3, 40)).astype(np.float32) * 100
    alpha = 0.3
    got = np.asarray(ewma(jnp.asarray(lat), alpha))
    want = lat[:, 0].copy()
    for t in range(lat.shape[1]):
        want = (1 - alpha) * want + alpha * lat[:, t]
    np.testing.assert_allclose(got, want, rtol=1e-5)


# ---------------------------------------------------------------------------
# Pallas kernel vs oracle — shape sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,T", [(1, 32), (7, 64), (256, 64), (300, 100), (512, 128)])
def test_qos_kernel_matches_oracle(n, T):
    rng = np.random.default_rng(n * 1000 + T)
    lat = (rng.random((n, T)).astype(np.float32) * 900 + 5)
    lat[0, -1] = 1500.0  # one offline server
    got = np.asarray(ops.qos_scores(jnp.asarray(lat)))
    want = np.asarray(network_score(jnp.asarray(lat)))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


def test_qos_kernel_custom_params():
    p = QosParams(window=16, ewma_alpha=0.5, w_outage=0.5)
    rng = np.random.default_rng(9)
    lat = (rng.random((64, 48)).astype(np.float32) * 1200).clip(1.0)
    got = np.asarray(ops.qos_scores(jnp.asarray(lat), p))
    want = np.asarray(network_score(jnp.asarray(lat), p))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)
