"""Routing algorithms (Sec. IV + V baselines)."""
import numpy as np
import pytest

from repro.core import dataset, platform, routing
from repro.core.routing import RoutingConfig, predict_tool_type

SERVERS = dataset.build_server_pool(seed=0)


def test_tool_prediction_websearch():
    intent, q_pre = predict_tool_type("Who painted the starry night?")
    assert intent == dataset.WEBSEARCH
    assert "search" in q_pre


def test_tool_prediction_hard_query_mispredicts():
    # the paper's failure mode: leading domain vocabulary drags intent away
    intent, _ = predict_tool_type(
        "Refactor my understanding: which code of law is the oldest written one?"
    )
    assert intent == "coding"


def test_rag_vs_prag_ssr():
    """RAG (raw query) picks websearch far less often than PRAG (Fig. 7)."""
    queries = dataset.build_query_dataset(n=60, seed=0)
    rag = routing.make_router("rag", SERVERS)
    prag = routing.make_router("prag", SERVERS)
    hit = lambda r, q: SERVERS[r.select(q.text).server_idx].domain == dataset.WEBSEARCH
    rag_ssr = np.mean([hit(rag, q) for q in queries])
    prag_ssr = np.mean([hit(prag, q) for q in queries])
    assert prag_ssr > 0.8
    assert rag_ssr < 0.5
    assert prag_ssr > rag_ssr + 0.3


def test_rerank_latency_cost():
    r = routing.make_router("rerank_rag", SERVERS)
    d = r.select("Who founded the first luxury goods company?")
    assert d.select_latency_ms > 20_000


def test_sonar_avoids_offline_server():
    plat = platform.NetMCPPlatform(SERVERS, scenario="hybrid", seed=1)
    prag = routing.make_router("prag", SERVERS)
    sonar = routing.make_router("sonar", SERVERS)
    # find a time when PRAG's top pick is offline
    q = "What is the capital city of australia?"
    for t in range(100, 6000, 50):
        hist = plat.latency_window(t)
        d_prag = prag.select(q, hist)
        if hist[d_prag.server_idx, -1] >= 1000.0:
            d_sonar = sonar.select(q, hist)
            assert hist[d_sonar.server_idx, -1] < 1000.0
            assert SERVERS[d_sonar.server_idx].domain == dataset.WEBSEARCH
            return
    pytest.fail("hybrid scenario never put the semantic-top server offline")


def test_alpha_beta_tradeoff():
    """Lower alpha (more network weight) must not pick higher-latency hosts."""
    plat = platform.NetMCPPlatform(SERVERS, scenario="fluctuating", seed=2)
    hist = plat.latency_window(3000)
    lat_picked = []
    for alpha in (0.9, 0.5, 0.1):
        r = routing.make_router(
            "sonar", SERVERS, RoutingConfig(alpha=alpha, beta=1 - alpha)
        )
        d = r.select("Which planet has the most moons?", hist)
        lat_picked.append(hist[d.server_idx, -1])
    assert lat_picked[2] <= lat_picked[0] + 1e-6


def test_decision_exposes_eq5_softmax():
    r = routing.make_router("sonar", SERVERS)
    plat = platform.NetMCPPlatform(SERVERS, scenario="ideal", seed=0)
    d = r.select("What year did the berlin wall fall?", plat.latency_window(10))
    assert 0.0 < d.expertise <= 1.0
    assert len(d.candidate_tools) <= r.cfg.top_k


def test_candidate_counts_respect_config():
    cfg = RoutingConfig(top_s=3, top_k=6)
    r = routing.make_router("prag", SERVERS, cfg)
    d = r.select("Who discovered penicillin?")
    assert len(d.candidate_servers) == 3
    assert len(d.candidate_tools) <= 6
