"""Golden weight trajectory for SONAR-ADAPT (PR-3 golden-trace pattern).

Frozen-seed artifact committed under ``tests/golden/adaptive/``:

  trajectory.npz — the scalar SONAR-ADAPT weight vector sampled every
                   ``SAMPLE_EVERY`` updates while the fleet simulator
                   drives it through the canonical chaos scenario
                   (``standard_fault_mix`` at intensity 0.8), plus the
                   final weights / baseline / step count

The trajectory is a deterministic function of (seed, scenario, update
rule): regenerating it from the same seed and comparing catches any
unintended change to the EG step, the reward shaping, the feedback
plumbing, or the simulator's outcome stream.  A sha256 manifest guards
the fixture itself against stray edits.

Regenerate (after an *intended* change to any of the above) with:

    PYTHONPATH=src python tests/test_golden_adaptive.py --regen
"""
import hashlib
import json
import pathlib

import jax
import numpy as np

from repro.core import latency as L
from repro.core.adaptive import AdaptConfig, SonarAdaptRouter
from repro.core.platform import NetMCPPlatform
from repro.core.routing import RoutingConfig
from repro.chaos import build_schedule, standard_fault_mix
from repro.traffic import (
    FleetTrafficSim,
    QueueConfig,
    poisson_arrivals,
    replica_fleet,
)

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden" / "adaptive"
TRAJ_NPZ = GOLDEN_DIR / "trajectory.npz"
MANIFEST = GOLDEN_DIR / "manifest.json"

SEED = 2024
N_SERVERS = 6
HORIZON_S, DT_S = 240.0, 1.0
RATE_RPS = 4.0
INTENSITY = 0.8
SAMPLE_EVERY = 8                 # weight-history sampling stride (updates)

QUERY_TEXTS = [
    "search the web for the latest news",
    "refactor this function in the repository",
    "what is the weather forecast tomorrow",
]

# Cross-platform slack (same rationale as tests/test_golden_traces.py):
# ULP-level transcendental drift across XLA versions, orders of magnitude
# below semantic drift — a dropped term or reordered feedback moves the
# trajectory by whole percent within a few updates.
RTOL, ATOL = 1e-4, 1e-2


def synth_trajectory() -> dict:
    servers = replica_fleet(N_SERVERS)
    n_steps = L.trace_horizon_steps(HORIZON_S, DT_S)
    faults = standard_fault_mix(INTENSITY, N_SERVERS, HORIZON_S)
    chaos = build_schedule(faults, N_SERVERS, n_steps, DT_S, seed=SEED)
    plat = NetMCPPlatform(
        servers,
        profiles=[L.ideal_profile() for _ in servers],
        scenario="ideal", seed=SEED, horizon_s=HORIZON_S, dt_s=DT_S,
        chaos=chaos,
    )
    cfg = RoutingConfig(top_s=N_SERVERS, top_k=N_SERVERS)
    router = SonarAdaptRouter(servers, cfg, adapt=AdaptConfig())
    arrivals = poisson_arrivals(
        jax.random.PRNGKey(SEED), RATE_RPS, HORIZON_S
    )
    sim = FleetTrafficSim(
        plat, router,
        QueueConfig(capacity=2, queue_limit=8, base_service_ms=150.0,
                    inflation=1.0),
        retry_budget=2, seed=SEED,
    )
    sim.run(arrivals, QUERY_TEXTS)
    hist = np.asarray(router.weight_history, np.float32)
    return {
        "sampled_weights": hist[::SAMPLE_EVERY].copy(),
        "final_weights": np.asarray(router.state.weights, np.float32),
        "final_baseline": np.float32(router.state.baseline),
        "n_updates": np.int64(router.state.step),
    }


def _sha256(path: pathlib.Path) -> str:
    return hashlib.sha256(path.read_bytes()).hexdigest()


def regen() -> None:
    GOLDEN_DIR.mkdir(parents=True, exist_ok=True)
    np.savez(TRAJ_NPZ, **synth_trajectory())
    MANIFEST.write_text(
        json.dumps({TRAJ_NPZ.name: _sha256(TRAJ_NPZ)}, indent=2) + "\n"
    )


# ---------------------------------------------------------------------------
# Drift tests
# ---------------------------------------------------------------------------

def test_trajectory_matches_golden():
    stored = np.load(TRAJ_NPZ)
    fresh = synth_trajectory()
    assert sorted(stored.files) == sorted(fresh)
    assert int(fresh["n_updates"]) == int(stored["n_updates"]), (
        "update count drifted — the simulator emits a different outcome "
        "stream (or feedback is dropped/duplicated somewhere)"
    )
    for name in ("sampled_weights", "final_weights", "final_baseline"):
        np.testing.assert_allclose(
            fresh[name], stored[name], rtol=RTOL, atol=ATOL,
            err_msg=f"adaptive trajectory field '{name}' drifted from the "
                    "golden fixture — regenerate via --regen if intentional",
        )


def test_golden_adaptive_fixture_integrity():
    """Fixture matches its committed checksum (guards hand-edits)."""
    manifest = json.loads(MANIFEST.read_text())
    assert manifest[TRAJ_NPZ.name] == _sha256(TRAJ_NPZ), (
        f"{TRAJ_NPZ.name} does not match its manifest checksum; "
        "regenerate via --regen"
    )


def test_golden_adaptive_fixture_has_expected_signatures():
    """Sanity on the frozen data itself: the learner genuinely learned.

    Under the chaos mix the reward stream is informative, so the weight
    trajectory must (a) contain a meaningful number of updates, (b) leave
    the shared init, and (c) stay inside the configured clip box at every
    sampled step.
    """
    t = np.load(TRAJ_NPZ)
    acfg = AdaptConfig()
    w = t["sampled_weights"]
    init = np.asarray(
        [RoutingConfig().alpha, RoutingConfig().beta,
         RoutingConfig().gamma, RoutingConfig().delta], np.float32
    )
    assert int(t["n_updates"]) >= 100
    assert w.shape[1] == 4
    assert (w >= acfg.w_min - 1e-6).all() and (w <= acfg.w_max + 1e-6).all()
    assert np.abs(t["final_weights"] - init).max() > 1e-3, (
        "frozen trajectory never left the shared init — the fixture "
        "would not exercise the learner"
    )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--regen", action="store_true")
    args = ap.parse_args()
    if args.regen:
        regen()
        print(f"regenerated fixtures under {GOLDEN_DIR}")
