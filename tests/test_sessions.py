"""Session-DAG workloads and SONAR-SESSION sticky-affinity routing.

Covers: DAG template shapes + topological order, deterministic critical
paths, the jax-seeded session generator, warmth decay/pruning, task-level
accounting (node conservation, abandon semantics), the warm-context
service discount, DAG-aware hedging, the four-path parity of
``sonar_session`` (including the zero-affinity byte-identity reduction to
``sonar_geo``), and the gateway's session threading + accounting fixes
(in-flight/gauge lockstep, begin/finish spans, pending-feats expiry).
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import dataset, routing
from repro.core.batch_routing import BatchRoutingEngine
from repro.core.latency import OFFLINE_MS
from repro.core.mesh_routing import ShardedRoutingEngine
from repro.core.routing import RoutingConfig
from repro.obs import Observability
from repro.sessions import (
    DAG_TEMPLATES,
    SessionTrafficSim,
    WarmthTracker,
    chain,
    critical_path,
    fanout_fanin,
    generate_sessions,
    map_reduce,
    retry_loop,
)
from repro.traffic import QueueConfig, ideal_platform, replica_fleet

POOL = dataset.build_server_pool(seed=0)
QUERY_TEXTS = [
    "search the web for the latest news",
    "refactor this function in the repository",
    "what is the weather forecast tomorrow",
    "summarize this long research document",
]


# ---------------------------------------------------------------------------
# DAG templates + critical path
# ---------------------------------------------------------------------------

def test_templates_are_topological_with_single_root_and_sink():
    dags = [
        chain(0, QUERY_TEXTS, n_steps=4),
        fanout_fanin(1, QUERY_TEXTS, width=3),
        retry_loop(2, QUERY_TEXTS, n_steps=3),
        map_reduce(3, QUERY_TEXTS, width=3, n_reduce=2),
    ]
    assert set(DAG_TEMPLATES) == {
        "chain", "fanout_fanin", "retry_loop", "map_reduce"
    }
    for dag in dags:
        # __post_init__ already asserts parents[j] < j; check the shape
        assert dag.roots() == [0]
        children = dag.children()
        sinks = [n.node_id for n in dag.nodes if not children[n.node_id]]
        assert sinks == [dag.n_nodes - 1]


def test_chain_and_retry_loop_critical_path_is_everything():
    for dag in (chain(0, QUERY_TEXTS, n_steps=5),
                retry_loop(1, QUERY_TEXTS, n_steps=2)):
        assert critical_path(dag) == frozenset(range(dag.n_nodes))


def test_fanout_critical_path_takes_lowest_id_branch():
    dag = fanout_fanin(0, QUERY_TEXTS, width=4)
    # root -> first parallel node -> sink, deterministically
    assert critical_path(dag) == frozenset({0, 1, 5})
    mr = map_reduce(1, QUERY_TEXTS, width=3, n_reduce=2)
    # split -> mapper 1 -> reducer 4 -> merge
    assert critical_path(mr) == frozenset({0, 1, 4, 6})


def test_generate_sessions_deterministic_and_composes_with_arrivals():
    kw = dict(rate=1.5, horizon_s=40.0, texts=QUERY_TEXTS,
              regions=np.array([0, 1, 2]))
    a = generate_sessions(jax.random.PRNGKey(7), **kw)
    b = generate_sessions(jax.random.PRNGKey(7), **kw)
    c = generate_sessions(jax.random.PRNGKey(8), **kw)
    assert len(a) == len(b) > 0
    for da, db in zip(a, b):
        assert (da.template, da.n_nodes, da.t_arrival_s, da.region) == (
            db.template, db.n_nodes, db.t_arrival_s, db.region
        )
        assert [n.text for n in da.nodes] == [n.text for n in db.nodes]
    assert any(
        da.t_arrival_s != dc.t_arrival_s for da, dc in zip(a, c)
    ), "different keys must give different workloads"
    arr = [d.t_arrival_s for d in a]
    assert arr == sorted(arr) and arr[-1] < 40.0
    assert {d.template for d in a} == set(DAG_TEMPLATES)
    assert all(d.region in (0, 1, 2) for d in a)
    # any registered arrival process slots in
    mmpp = generate_sessions(
        jax.random.PRNGKey(7), 1.5, 40.0, QUERY_TEXTS,
        arrival_process="mmpp", burst_factor=6.0,
    )
    assert len(mmpp) > 0


# ---------------------------------------------------------------------------
# Warmth
# ---------------------------------------------------------------------------

def test_warmth_decays_by_half_life_and_prunes():
    w = WarmthTracker(4, half_life_ms=100.0, floor=1e-3)
    assert w.warmth(5, 0.0) is None          # untracked: exact-zero path
    w.touch(5, 2, 0.0)
    np.testing.assert_array_equal(w.warmth(5, 0.0), [0, 0, 1, 0])
    got = w.warmth(5, 100.0)
    assert got[2] == pytest.approx(0.5) and got.max() == got[2]
    w.touch(5, 1, 100.0)                     # second server joins warm set
    got = w.warmth(5, 200.0)
    assert got[1] == pytest.approx(0.5) and got[2] == pytest.approx(0.25)
    assert w.warmth(5, 5000.0) is None       # fully cooled: pruned
    assert len(w) == 0
    w.touch(6, 0, 0.0)
    w.forget(6)
    assert len(w) == 0 and w.warmth(6, 0.0) is None


# ---------------------------------------------------------------------------
# Session simulator: conservation, abandonment, warm discount, hedging
# ---------------------------------------------------------------------------

def _session_sim(n_servers=4, algo="sonar_session", queue_limit=64,
                 retry_budget=2, hedge_ms=None, horizon_s=240.0, **kw):
    servers = replica_fleet(n_servers)
    plat = ideal_platform(servers, seed=0, horizon_s=4.0 * horizon_s)
    router = routing.make_router(
        algo, servers, RoutingConfig(top_s=min(4, n_servers), top_k=4)
    )
    return SessionTrafficSim(
        plat, router,
        QueueConfig(capacity=2, queue_limit=queue_limit,
                    base_service_ms=120.0),
        retry_budget=retry_budget, hedge_ms=hedge_ms, seed=0, **kw,
    )


def _workload(rate=0.8, horizon_s=240.0, key=3, **kw):
    return generate_sessions(
        jax.random.PRNGKey(key), rate, horizon_s, QUERY_TEXTS, **kw
    )


def test_session_sim_conserves_nodes_and_settles_every_task():
    sim = _session_sim()
    rep = sim.run_sessions(_workload())
    rep.check_accounting()                   # offered == completed+failed
    assert rep.n_sessions > 20
    total = (rep.n_nodes_completed + rep.n_nodes_failed
             + rep.n_nodes_abandoned)
    assert total == sum(d.n_nodes for d in _workload())
    assert set(rep.per_template) <= set(DAG_TEMPLATES)
    # registry mirrors the report tallies
    reg = sim.obs.registry
    assert reg.value("task_offered_total") == rep.n_sessions
    assert reg.value("task_completed_total") == rep.n_tasks_succeeded
    assert reg.value("task_failed_total") == rep.n_tasks_failed
    assert reg.value("task_nodes_released_total") == rep.n_nodes_offered
    assert reg.value("task_nodes_abandoned_total") == rep.n_nodes_abandoned


def test_session_sim_deterministic_replay():
    a = _session_sim().run_sessions(_workload())
    b = _session_sim().run_sessions(_workload())
    assert a.task_success_rate == b.task_success_rate
    assert a.task_p99_ms == b.task_p99_ms
    assert [r.server_idx for r in a.requests] == [
        r.server_idx for r in b.requests
    ]


def test_failed_node_abandons_descendants_not_ancestors():
    # tiny queues + no retries under overload: plenty of node failures
    sim = _session_sim(n_servers=2, queue_limit=2, retry_budget=0)
    rep = sim.run_sessions(_workload(rate=3.0, key=5))
    assert rep.n_tasks_failed > 0 and rep.n_nodes_abandoned > 0
    abandoned = [r for r in rep.requests if r.node_id >= 0
                 and not r.done and not r.failed and r.n_routes == 0]
    # every abandoned node was never offered to the fleet
    assert len(abandoned) == rep.n_nodes_abandoned
    # a successful task abandons nothing: its nodes all completed
    by_sid: dict = {}
    for r in rep.requests:
        by_sid.setdefault(r.session_id, []).append(r)
    for sid, reqs in by_sid.items():
        if all(r.done for r in reqs):
            continue
        assert any(r.failed for r in reqs) or any(
            not r.done and r.n_routes == 0 for r in reqs
        )


def test_warm_context_discount_speeds_up_sticky_sessions():
    """With warm_speedup < 1 a chain session re-hitting the same server
    runs faster than the identical cold-fleet run."""
    sessions = [chain(i, QUERY_TEXTS, n_steps=5) for i in range(12)]
    for i, s in enumerate(sessions):
        s.t_arrival_s = 6.0 * i
    warm = _session_sim(n_servers=2, warm_speedup=0.5,
                        warmth_half_life_ms=60_000.0)
    cold = _session_sim(n_servers=2, warm_speedup=1.0,
                        warmth_half_life_ms=60_000.0)
    rw = warm.run_sessions(sessions)
    rc = cold.run_sessions(sessions)
    assert rw.task_success_rate == rc.task_success_rate == 1.0
    assert rw.task_mean_ms < rc.task_mean_ms


def test_hedging_is_restricted_to_critical_path_nodes():
    sim = _session_sim(n_servers=3, hedge_ms=30.0, queue_limit=8)
    rep = sim.run_sessions(_workload(rate=2.5, key=9))
    rep.check_accounting()
    hedged = [r for r in rep.requests if r.n_hedges > 0]
    assert all(r.hedge_ok for r in hedged), (
        "only critical-path nodes may hedge"
    )
    off_path = [r for r in rep.requests if not r.hedge_ok]
    assert off_path, "workload should contain off-critical-path nodes"
    assert all(r.n_hedges == 0 for r in off_path)


# ---------------------------------------------------------------------------
# SONAR-SESSION four-path parity
# ---------------------------------------------------------------------------

def _materialize(seed, n_servers, identical):
    rng = np.random.default_rng(seed)
    if identical:
        servers = replica_fleet(n_servers)
    else:
        pick = rng.choice(len(POOL), size=n_servers, replace=False)
        servers = [POOL[i] for i in pick]
    hist = rng.uniform(5.0, 400.0, size=(n_servers, 24)).astype(np.float32)
    down = rng.random(n_servers) < 0.2
    hist[down, -1] = OFFLINE_MS + 50.0
    load = (rng.random(n_servers) * 2.0).astype(np.float32)
    rtt = (rng.random(n_servers) * 500.0).astype(np.float32)
    aff = rng.random((len(QUERY_TEXTS), n_servers)).astype(np.float32)
    aff[rng.random(len(QUERY_TEXTS)) < 0.3] = 0.0    # some cold rows
    return servers, hist, load, rtt, aff


def _four_paths(servers, cfg, algo, index):
    yield "batch(jnp)", BatchRoutingEngine(
        servers, cfg, algo=algo, use_kernels=False, index=index
    )
    yield "batch(kernels)", BatchRoutingEngine(
        servers, cfg, algo=algo, use_kernels=True, interpret=True,
        index=index,
    )
    yield "sharded", ShardedRoutingEngine(
        servers, cfg, algo=algo, n_shards=min(3, len(servers)),
        use_kernels=False, index=index,
    )


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_servers=st.integers(2, 6),
    identical=st.booleans(),
)
def test_zero_affinity_session_byte_identical_to_sonar_geo(
    seed, n_servers, identical
):
    """Acceptance gate: with no affinity operand SONAR-SESSION is
    byte-identical to SONAR-GEO on every decision field across all four
    routing paths — the ``+eps*W`` term compiles away entirely."""
    servers, hist, load, rtt, _aff = _materialize(seed, n_servers, identical)
    cfg = RoutingConfig(top_s=min(4, n_servers), top_k=5)
    r_geo = routing.make_router("sonar_geo", servers, cfg)
    r_ses = routing.make_router("sonar_session", servers, cfg)
    for q in QUERY_TEXTS:
        a = r_geo.select(q, hist, load, client_rtt_ms=rtt)
        b = r_ses.select(q, hist, load, client_rtt_ms=rtt)
        assert (
            a.server_idx, a.tool_idx, a.expertise, a.network, a.fused
        ) == (b.server_idx, b.tool_idx, b.expertise, b.network, b.fused)
    for (label, e_geo), (_, e_ses) in zip(
        _four_paths(servers, cfg, "sonar_geo", r_geo.index),
        _four_paths(servers, cfg, "sonar_session", r_geo.index),
    ):
        da = e_geo.route_texts(QUERY_TEXTS, hist, load, None, None, rtt)
        db = e_ses.route_texts(QUERY_TEXTS, hist, load, None, None, rtt)
        for field in ("server_idx", "tool_idx", "expertise", "network",
                      "fused"):
            np.testing.assert_array_equal(
                getattr(da, field), getattr(db, field),
                err_msg=f"{label} field={field}",
            )


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_servers=st.integers(2, 6),
    identical=st.booleans(),
    broadcast=st.booleans(),     # one shared warmth row vs per-query rows
)
def test_sonar_session_affinity_parity_four_paths(
    seed, n_servers, identical, broadcast
):
    """With a live affinity operand, scalar select, the jit engine, the
    fused Pallas path and the mesh-sharded engine agree on every decision
    field — warmth rides as data, so no path recompiles or diverges."""
    servers, hist, load, rtt, aff = _materialize(seed, n_servers, identical)
    if broadcast:
        aff = np.broadcast_to(aff[0], aff.shape).copy()
    cfg = RoutingConfig(top_s=min(4, n_servers), top_k=5)
    router = routing.make_router("sonar_session", servers, cfg)
    scalar = []
    for i, q in enumerate(QUERY_TEXTS):
        d = router.select(
            q, hist, load, client_rtt_ms=rtt, affinity=aff[i]
        )
        scalar.append(d)
    eng_aff = aff[0] if broadcast else aff        # exercise 1D and 2D
    decs = []
    for label, eng in _four_paths(servers, cfg, "sonar_session",
                                  router.index):
        dec = eng.route_texts(
            QUERY_TEXTS, hist, load, None, None, rtt, affinity=eng_aff
        )
        decs.append((label, dec))
        for i, d in enumerate(scalar):
            got = (int(dec.server_idx[i]), int(dec.tool_idx[i]))
            assert got == (d.server_idx, d.tool_idx), (
                f"{label} query={i}: {got} != "
                f"{(d.server_idx, d.tool_idx)}"
            )
            # scalar numpy and the jit/fused paths may associate the
            # +eps*W add differently (ulp-level slack, same as the other
            # cross-path score comparisons); the argmax contract is exact
            np.testing.assert_allclose(
                np.float32(dec.fused[i]), np.float32(d.fused),
                rtol=1e-4, atol=1e-6, err_msg=f"{label} query={i} fused",
            )
    # every batched path picks the same winners
    ref_label, ref = decs[0]
    for label, dec in decs[1:]:
        for field in ("server_idx", "tool_idx"):
            np.testing.assert_array_equal(
                getattr(ref, field), getattr(dec, field),
                err_msg=f"{ref_label} vs {label} field={field}",
            )


def test_sonar_session_sticks_to_warm_server_on_ties():
    """Identical replicas + identical telemetry: the warmth bonus is the
    only tiebreaker, so the warm server must win."""
    servers = replica_fleet(5)
    hist = np.full((5, 16), 50.0, np.float32)
    load = np.zeros(5, np.float32)
    # top_k covers every replica's tool: affinity re-ranks candidates,
    # it never resurrects tools stage 2 already truncated away
    cfg = RoutingConfig(top_s=5, top_k=8)
    router = routing.make_router("sonar_session", servers, cfg)
    cold = router.select(QUERY_TEXTS[0], hist, load)
    for warm_idx in range(5):
        aff = np.zeros(5, np.float32)
        aff[warm_idx] = 1.0
        d = router.select(QUERY_TEXTS[0], hist, load, affinity=aff)
        assert d.server_idx == warm_idx, (
            f"warm server {warm_idx} lost the tie to {d.server_idx}"
        )
        assert d.fused >= cold.fused


# ---------------------------------------------------------------------------
# Gateway: session threading + accounting fixes
# ---------------------------------------------------------------------------

def _gateway(algo="sonar_session", n=4, **kw):
    from repro.serving.gateway import SonarGateway
    servers = replica_fleet(n)
    return SonarGateway(
        servers, algo=algo, cfg=RoutingConfig(top_s=4, top_k=4), **kw
    )


def test_gateway_finish_gauge_moves_in_lockstep_with_array():
    """Regression (accounting desync): an unmatched finish used to clamp
    the in-flight array at 0 but still decrement the gauge, driving it
    negative.  Now both stay put and the finish is counted + rejected."""
    gw = _gateway(algo="sonar_lb")
    r = gw.begin("generate text")
    assert gw.finish(r.replica_idx, 25.0) is not None
    for _ in range(3):                       # double/triple finish: rejected
        assert gw.finish(r.replica_idx, 25.0) is None
    rep = gw.report()
    assert rep["in_flight"] == 0.0, "gauge must never go negative"
    assert rep["unmatched_finish"] == 3.0
    assert np.all(gw.in_flight == 0.0)
    assert rep["n"] == 1                     # rejected finishes not accounted
    # finishes on a replica that never began are rejected too
    assert gw.finish(0, 10.0) is None and gw.report()["in_flight"] == 0.0


def test_gateway_begin_and_finish_emit_gateway_spans():
    """Regression: route() traced its selection but begin() didn't; the
    begin/finish path now tiles the gateway track the same way."""
    gw = _gateway(algo="sonar_lb", obs=Observability(trace=True))
    r = gw.begin("generate text")
    gw.finish(r.replica_idx, 25.0)
    gw.finish(r.replica_idx, 25.0)           # unmatched: instant, no span
    gw.route("generate text")
    events = gw.obs.tracer.events
    spans = [e for e in events if e.get("cat") == "gateway"]
    names = [e["name"] for e in spans]
    assert names.count("begin") == 1
    assert names.count("finish") == 1        # the rejected finish: no span
    assert names.count("route") == 1
    for e in spans:
        if e["ph"] == "X":
            assert e["dur"] >= 0.0
    assert any(
        e["name"] == "unmatched_finish" for e in events
    )


def test_gateway_abandon_releases_slot_and_expires_feats():
    gw = _gateway(algo="sonar_adapt")
    assert gw.adaptive
    a = gw.begin("generate text")
    b = gw.begin("generate text")
    outstanding = {a.replica_idx: 0, b.replica_idx: 0}
    for r in (a, b):
        outstanding[r.replica_idx] += 1
    assert gw.abandon(a.replica_idx) is True
    outstanding[a.replica_idx] -= 1
    fifo = gw._pending_feats.get(a.replica_idx, [])
    assert len(fifo) == outstanding[a.replica_idx]
    assert float(gw.in_flight.sum()) == sum(outstanding.values())
    assert gw.report()["in_flight"] == float(gw.in_flight.sum())
    # abandoning an idle replica is rejected, not under-flowed
    idle = next(i for i in range(4) if gw.in_flight[i] == 0.0)
    assert gw.abandon(idle) is False
    assert gw.report()["in_flight"] == float(gw.in_flight.sum())


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    ops=st.lists(st.sampled_from(["begin", "finish", "abandon"]),
                 min_size=1, max_size=40),
)
def test_gateway_feats_pairing_under_interleaved_begin_shed_finish(
    seed, ops
):
    """Property (adaptive credit assignment): under any interleaving of
    begin / abandon (shed) / finish, per-replica pending-feats depth
    always equals the replica's outstanding in-flight count, the gauge
    equals the array sum, and neither ever goes negative."""
    gw = _gateway(algo="sonar_adapt")
    rng = np.random.default_rng(seed)
    n = len(gw.replicas)
    for op in ops:
        if op == "begin":
            gw.begin("generate text")
        else:
            idx = int(rng.integers(n))
            if op == "finish":
                gw.finish(idx, float(rng.uniform(5.0, 80.0)))
            else:
                gw.abandon(idx)
        assert np.all(gw.in_flight >= 0.0)
        assert gw.report()["in_flight"] == float(gw.in_flight.sum())
        for idx in range(n):
            fifo = gw._pending_feats.get(idx, [])
            assert len(fifo) == int(gw.in_flight[idx]), (
                f"replica {idx}: feats depth {len(fifo)} != "
                f"outstanding {gw.in_flight[idx]}"
            )


def test_gateway_session_affinity_is_sticky_end_to_end():
    """A session's completions warm the winning replica; identical
    replicas then keep routing the session there across begin/finish,
    route, and route_batch."""
    gw = _gateway(algo="sonar_session", use_kernels=True)
    first = gw.begin("generate text", session_id=11)
    gw.finish(first.replica_idx, 20.0, session_id=11)
    again = gw.route("generate text", session_id=11)
    assert again.replica_idx == first.replica_idx
    out = gw.route_batch(["generate text"] * 6,
                         session_ids=[11, None, 11, 11, None, 11])
    tagged = [r.replica_idx for r, s in
              zip(out, [11, None, 11, 11, None, 11]) if s == 11]
    assert all(idx == first.replica_idx for idx in tagged)
    # session-less traffic through the same gateway is unaffected state
    assert np.all(gw.in_flight == 0.0)


def test_gateway_sessionless_route_batch_matches_sonar_geo_gateway():
    """With no session tags a sonar_session gateway routes exactly like
    a sonar_geo one (the serving-level zero-affinity reduction)."""
    texts = ["generate text", "search the web", "generate text"] * 3
    a = _gateway(algo="sonar_geo", use_kernels=True)
    b = _gateway(algo="sonar_session", use_kernels=True)
    ra = [r.replica_idx for r in a.route_batch(texts)]
    rb = [r.replica_idx for r in b.route_batch(texts)]
    assert ra == rb
